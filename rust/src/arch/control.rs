//! The Main Control Unit: drives the full weight-stationary pipeline —
//! Weight Fetcher, Systolic Data Setup, PE array, Accumulator Array,
//! Unified Buffer — over the tile schedule shared with the analytic model,
//! and assembles the final [`Metrics`]. Output-stationary configurations
//! are emulated too: their numerics follow the literal OS tile walk and
//! their timing/counters route through the event-driven `sim` backend
//! (DESIGN.md §13).
//!
//! WS timing follows the double-buffered recurrence of DESIGN.md §3: the
//! fetcher starts loading pass p's tile when pass p-1 begins computing, so
//! `start(p) = max(end(p-1), start(p-1) + load(p))` and the first pass
//! exposes its whole load.

use crate::arch::accumulator::AccumulatorArray;
use crate::arch::array::SystolicArray;
use crate::arch::fifo::SystolicDataSetup;
use crate::arch::unified_buffer::UnifiedBuffer;
use crate::arch::weight_fetcher::WeightFetcher;
use crate::config::{ArrayConfig, ConfigError, Dataflow};
use crate::metrics::{Metrics, MovementCounters};
use crate::model::schedule::{GemmShape, OsSchedule, WsSchedule};
use crate::sim;
use crate::sim::trace::TraceSink;
use crate::tensor::Matrix;

/// Which array engine streams the passes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EmulationMode {
    /// Fast wavefront-ordered event emulation (default).
    Wavefront,
    /// Literal cycle-stepped grid emulation (validation; O(cycles · PEs)).
    CycleAccurate,
}

/// Result of functionally emulating one GEMM.
#[derive(Debug)]
pub struct EmulationResult {
    pub output: Matrix,
    pub metrics: Metrics,
    /// Peak SDS FIFO staging depth observed (FIFO sizing signal).
    pub max_fifo_depth: usize,
}

/// The emulator instance the wrapper library creates per configuration
/// (paper §3: "dynamically creates emulator instances of certain
/// configurations").
#[derive(Debug, Clone)]
pub struct Emulator {
    cfg: ArrayConfig,
}

impl Emulator {
    /// Build an emulator for a validated configuration. Both dataflows are
    /// supported: weight-stationary runs the in-crate functional pipeline
    /// below; output-stationary routes timing and movement counters
    /// through the event-driven `sim` backend while the numerics follow
    /// the literal OS tile walk.
    pub fn new(cfg: ArrayConfig) -> Result<Emulator, ConfigError> {
        cfg.validate()?;
        Ok(Emulator { cfg })
    }

    pub fn config(&self) -> &ArrayConfig {
        &self.cfg
    }

    /// Emulate `C = A * W` and return the computed output plus metrics.
    pub fn run_gemm(&self, a: &Matrix, w: &Matrix, mode: EmulationMode) -> EmulationResult {
        assert_eq!(a.cols, w.rows, "GEMM inner dimensions");
        match self.cfg.dataflow {
            Dataflow::WeightStationary => self.run_gemm_ws(a, w, mode),
            Dataflow::OutputStationary => self.run_gemm_os(a, w),
        }
    }

    fn run_gemm_ws(&self, a: &Matrix, w: &Matrix, mode: EmulationMode) -> EmulationResult {
        let gemm = GemmShape::new(a.rows, a.cols, w.cols);
        let sched = WsSchedule::new(gemm, &self.cfg);

        let mut ub = UnifiedBuffer::new(a.clone(), w.clone());
        let mut array = SystolicArray::new(self.cfg.height, self.cfg.width);
        let mut aa = AccumulatorArray::new(self.cfg.acc_capacity);
        let mut fetcher = WeightFetcher::new();

        let mut cycles: u64 = 0;
        let mut stall: u64 = 0;
        let mut passes: u64 = 0;
        let mut prev_compute: Option<u64> = None;
        let mut max_fifo_depth = 0usize;

        let mut current_window: Option<(usize, usize)> = None; // (rows, cols)

        for p in sched.passes() {
            // --- weight pipeline timing ---
            let tile = fetcher.fetch_tile(
                &mut ub,
                p.i,
                p.j,
                self.cfg.height,
                self.cfg.width,
                p.k_t,
                p.n_t,
            );
            let load = WeightFetcher::load_cycles(&tile);
            match prev_compute {
                None => cycles += load, // first load fully exposed
                Some(d_prev) => {
                    let s = load.saturating_sub(d_prev);
                    cycles += s;
                    stall += s;
                }
            }
            array.load_shadow_tile(&tile);
            array.activate_tile(p.k_t, p.n_t);

            // --- open the accumulator window at the first row-tile ---
            if p.i == 0 {
                debug_assert!(current_window.is_none(), "window left open");
                aa.open(p.mc, p.n_t);
                current_window = Some((p.mc, p.n_t));
            }

            // --- stage activations (UB reads through the SDS) ---
            let mut sds = SystolicDataSetup::new(self.cfg.height);
            let mut act_rows: Vec<Vec<f32>> = Vec::with_capacity(p.mc);
            for r in 0..p.mc {
                let row: Vec<f32> = (0..p.k_t)
                    .map(|d| ub.read_act(p.row_start + r, p.i * self.cfg.height + d))
                    .collect();
                if mode == EmulationMode::CycleAccurate {
                    sds.stage_row(r as u64, &row);
                }
                act_rows.push(row);
            }
            // In CycleAccurate mode the staged depth is literally measured
            // (`sds.max_depth() == Mc`); the wavefront engine skips the
            // staging but the same rows are held, so both modes report the
            // same peak — and it matches `sim::gemm_fifo_depth`.
            debug_assert!(mode == EmulationMode::Wavefront || sds.max_depth() == p.mc);
            max_fifo_depth = max_fifo_depth.max(p.mc);

            // --- stream ---
            // Pass duration is Mc + h + n_t - 2 (full-height drain); the
            // cycle engine steps the active region (Mc + k_t + n_t - 2)
            // and the remaining (h - k_t) descent cycles are pass-through.
            let d = match mode {
                EmulationMode::Wavefront => {
                    array.stream_pass_wavefront(&act_rows, &mut aa);
                    p.compute_cycles()
                }
                EmulationMode::CycleAccurate => {
                    let stepped = array.stream_pass_cycle(&mut sds, p.mc, &mut aa);
                    assert!(sds.is_empty(), "SDS drained");
                    assert_eq!(stepped, (p.mc + p.k_t + p.n_t - 2) as u64);
                    stepped + (self.cfg.height - p.k_t) as u64
                }
            };
            cycles += d;
            prev_compute = Some(d);
            passes += 1;

            // --- drain the finished chunk ---
            if p.writeback_after {
                let (_rows, _cols) = current_window.take().expect("window open");
                let base_row = p.row_start;
                let base_col = p.j * self.cfg.width;
                aa.drain(|r, c, v| ub.write_out(base_row + r, base_col + c, v));
            }
        }
        debug_assert!(current_window.is_none());

        let movements = MovementCounters {
            ub_act_reads: ub.act_reads,
            ub_weight_reads: ub.weight_reads,
            ub_out_writes: ub.out_writes,
            inter_pe_act: array.counters.inter_act,
            inter_pe_psum: array.counters.inter_psum,
            inter_pe_weight: array.counters.inter_weight,
            intra_pe: array.counters.intra,
            aa_writes: aa.writes,
            aa_reads: aa.reads,
        };
        let metrics = Metrics {
            cycles,
            stall_cycles: stall,
            macs: array.counters.macs,
            passes,
            movements,
        };
        EmulationResult {
            output: ub.into_output(),
            metrics,
            max_fifo_depth,
        }
    }

    /// Output-stationary emulation: walk the `OsSchedule` tile grid and
    /// perform the literal in-place accumulation the dataflow pins into
    /// the PEs (each `(mt x nt)` tile of C accumulates across the full
    /// reduction depth while A and W stream through). Timing and movement
    /// counters come from the event-driven `sim` pipeline — the same
    /// backend the property tests hold byte-identical to `os_metrics` —
    /// so the emulator and the analytic model cannot drift. The
    /// `EmulationMode` distinction is WS-specific (it selects how the
    /// wavefront is stepped); OS has a single engine.
    fn run_gemm_os(&self, a: &Matrix, w: &Matrix) -> EmulationResult {
        let gemm = GemmShape::new(a.rows, a.cols, w.cols);
        let sched = OsSchedule::new(gemm, &self.cfg);
        let mut out = Matrix::zeros(a.rows, w.cols);
        for t in sched.tiles() {
            for r in t.row_start..t.row_start + t.mt {
                for c in t.col_start..t.col_start + t.nt {
                    let mut acc = 0.0f32;
                    for kk in 0..t.k {
                        acc += a[(r, kk)] * w[(kk, c)];
                    }
                    out[(r, c)] = acc;
                }
            }
        }
        let simulated = sim::simulate_gemm(gemm, &self.cfg, &mut TraceSink::Off);
        EmulationResult {
            output: out,
            metrics: simulated.metrics,
            max_fifo_depth: simulated.max_fifo_depth,
        }
    }

    /// Emulate a grouped layer: `groups` independent GEMMs with
    /// block-diagonal weights. `a` is `M x (groups * K_g)`, `w` is a vec of
    /// per-group `K_g x N_g` matrices; output is `M x (groups * N_g)`.
    pub fn run_grouped(
        &self,
        a: &Matrix,
        w_groups: &[Matrix],
        mode: EmulationMode,
    ) -> EmulationResult {
        assert!(!w_groups.is_empty());
        let groups = w_groups.len();
        let k_g = w_groups[0].rows;
        let n_g = w_groups[0].cols;
        assert!(w_groups.iter().all(|w| w.rows == k_g && w.cols == n_g));
        assert_eq!(a.cols, groups * k_g);

        let mut out = Matrix::zeros(a.rows, groups * n_g);
        let mut metrics = Metrics::default();
        let mut max_fifo = 0usize;
        for (g, w) in w_groups.iter().enumerate() {
            let a_g = Matrix::from_fn(a.rows, k_g, |r, c| a[(r, g * k_g + c)]);
            let res = self.run_gemm(&a_g, w, mode);
            for r in 0..a.rows {
                for c in 0..n_g {
                    out[(r, g * n_g + c)] = res.output[(r, c)];
                }
            }
            metrics += res.metrics;
            max_fifo = max_fifo.max(res.max_fifo_depth);
        }
        EmulationResult {
            output: out,
            metrics,
            max_fifo_depth: max_fifo,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gemm::{ws_metrics, ws_metrics_ref};
    use crate::util::prng::Rng;

    fn cfg(h: usize, w: usize, acc: usize) -> ArrayConfig {
        ArrayConfig::new(h, w).with_acc_capacity(acc)
    }

    #[test]
    fn invalid_config_yields_typed_error() {
        let c = ArrayConfig {
            height: 0,
            ..cfg(4, 4, 64)
        };
        assert_eq!(Emulator::new(c).unwrap_err(), ConfigError::ZeroHeight);
    }

    #[test]
    fn output_stationary_matches_matmul_and_closed_form() {
        use crate::model::gemm::os_metrics;
        let mut rng = Rng::new(41);
        let c = cfg(4, 3, 16).with_dataflow(Dataflow::OutputStationary);
        let emu = Emulator::new(c.clone()).unwrap();
        let a = Matrix::random_small_int(7, 10, &mut rng);
        let w = Matrix::random_small_int(10, 8, &mut rng);
        let res = emu.run_gemm(&a, &w, EmulationMode::Wavefront);
        assert_eq!(res.output, a.matmul(&w));
        assert_eq!(res.metrics, os_metrics(GemmShape::new(7, 10, 8), &c));
        assert_eq!(res.max_fifo_depth, 4); // min(M, h)
        // The mode distinction is WS-specific; OS has one engine.
        let ca = emu.run_gemm(&a, &w, EmulationMode::CycleAccurate);
        assert_eq!(ca.output, res.output);
        assert_eq!(ca.metrics, res.metrics);
    }

    #[test]
    fn numerics_match_reference_matmul() {
        let mut rng = Rng::new(99);
        let emu = Emulator::new(cfg(4, 3, 16)).unwrap();
        let a = Matrix::random_small_int(7, 10, &mut rng);
        let w = Matrix::random_small_int(10, 8, &mut rng);
        let res = emu.run_gemm(&a, &w, EmulationMode::Wavefront);
        assert_eq!(res.output, a.matmul(&w));
    }

    #[test]
    fn both_modes_identical() {
        let mut rng = Rng::new(5);
        let emu = Emulator::new(cfg(3, 5, 8)).unwrap();
        let a = Matrix::random_small_int(6, 7, &mut rng);
        let w = Matrix::random_small_int(7, 9, &mut rng);
        let wf = emu.run_gemm(&a, &w, EmulationMode::Wavefront);
        let ca = emu.run_gemm(&a, &w, EmulationMode::CycleAccurate);
        assert_eq!(wf.output, ca.output);
        assert_eq!(wf.metrics, ca.metrics);
        assert_eq!(wf.max_fifo_depth, ca.max_fifo_depth);
    }

    #[test]
    fn emulator_matches_analytic_model_exactly() {
        let mut rng = Rng::new(17);
        for _ in 0..40 {
            let m = rng.range_usize(1, 12);
            let k = rng.range_usize(1, 12);
            let n = rng.range_usize(1, 12);
            let h = rng.range_usize(1, 6);
            let w = rng.range_usize(1, 6);
            let acc = rng.range_usize(1, 24);
            let c = cfg(h, w, acc);
            let emu = Emulator::new(c.clone()).unwrap();
            let a = Matrix::random_small_int(m, k, &mut rng);
            let wm = Matrix::random_small_int(k, n, &mut rng);
            let res = emu.run_gemm(&a, &wm, EmulationMode::Wavefront);
            let gemm = GemmShape::new(m, k, n);
            assert_eq!(
                res.metrics,
                ws_metrics(gemm, &c),
                "closed form mismatch M{m} K{k} N{n} h{h} w{w} acc{acc}"
            );
            assert_eq!(res.metrics, ws_metrics_ref(gemm, &c));
        }
    }

    #[test]
    fn grouped_layer_block_diagonal() {
        let mut rng = Rng::new(23);
        let emu = Emulator::new(cfg(4, 4, 32)).unwrap();
        let groups = 3;
        let (m, k_g, n_g) = (5, 4, 2);
        let a = Matrix::random_small_int(m, groups * k_g, &mut rng);
        let ws: Vec<Matrix> = (0..groups)
            .map(|_| Matrix::random_small_int(k_g, n_g, &mut rng))
            .collect();
        let res = emu.run_grouped(&a, &ws, EmulationMode::Wavefront);
        // Reference: per-group matmul.
        for g in 0..groups {
            let a_g = Matrix::from_fn(m, k_g, |r, c| a[(r, g * k_g + c)]);
            let expect = a_g.matmul(&ws[g]);
            for r in 0..m {
                for c in 0..n_g {
                    assert_eq!(res.output[(r, g * n_g + c)], expect[(r, c)]);
                }
            }
        }
        // Metrics are the serialized sum: equal to groups x one GEMM.
        let one = ws_metrics(GemmShape::new(m, k_g, n_g), emu.config());
        let mut expect = Metrics::default();
        for _ in 0..groups {
            expect += one;
        }
        assert_eq!(res.metrics, expect);
    }

    #[test]
    fn fifo_depth_reported_in_cycle_mode() {
        let emu = Emulator::new(cfg(4, 2, 64)).unwrap();
        let mut rng = Rng::new(31);
        let a = Matrix::random_small_int(6, 4, &mut rng);
        let w = Matrix::random_small_int(4, 2, &mut rng);
        let res = emu.run_gemm(&a, &w, EmulationMode::CycleAccurate);
        // Rows staged ahead of consumption force nonzero staging depth.
        assert!(res.max_fifo_depth > 0);
    }
}
