//! The Weight Fetcher: moves weight-matrix tiles from the Unified Buffer
//! into the array's shadow registers. Loads are double buffered — the
//! fetcher starts on pass p+1's tile the moment pass p begins computing —
//! and the control unit charges any exposed load time as stall.

use crate::arch::unified_buffer::UnifiedBuffer;

/// A staged weight tile in fetch order (row-major over the active extent).
#[derive(Debug, Clone)]
pub struct WeightTile {
    pub k_t: usize,
    pub n_t: usize,
    pub values: Vec<f32>,
}

impl WeightTile {
    #[inline]
    pub fn at(&self, d: usize, c: usize) -> f32 {
        debug_assert!(d < self.k_t && c < self.n_t);
        self.values[d * self.n_t + c]
    }
}

#[derive(Debug, Default)]
pub struct WeightFetcher {
    pub tiles_fetched: u64,
    pub words_fetched: u64,
}

impl WeightFetcher {
    pub fn new() -> WeightFetcher {
        WeightFetcher::default()
    }

    /// Fetch tile (i, j) of the weight matrix: rows `i*height ..`, cols
    /// `j*width ..`, active extent `k_t x n_t`. Every word read is counted
    /// by the Unified Buffer.
    pub fn fetch_tile(
        &mut self,
        ub: &mut UnifiedBuffer,
        i: usize,
        j: usize,
        height: usize,
        width: usize,
        k_t: usize,
        n_t: usize,
    ) -> WeightTile {
        let mut values = Vec::with_capacity(k_t * n_t);
        for d in 0..k_t {
            for c in 0..n_t {
                values.push(ub.read_weight(i * height + d, j * width + c));
            }
        }
        self.tiles_fetched += 1;
        self.words_fetched += (k_t * n_t) as u64;
        WeightTile { k_t, n_t, values }
    }

    /// Cycles to push a staged tile into the array: one weight row per
    /// cycle down the columns.
    pub fn load_cycles(tile: &WeightTile) -> u64 {
        tile.k_t as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    #[test]
    fn fetches_the_right_window() {
        let w = Matrix::from_fn(6, 6, |r, c| (r * 10 + c) as f32);
        let mut ub = UnifiedBuffer::new(Matrix::zeros(1, 6), w);
        let mut wf = WeightFetcher::new();
        // Tile (1, 1) on a 4x4 array over a 6x6 matrix: extent 2x2,
        // window rows 4..6, cols 4..6.
        let t = wf.fetch_tile(&mut ub, 1, 1, 4, 4, 2, 2);
        assert_eq!(t.at(0, 0), 44.0);
        assert_eq!(t.at(0, 1), 45.0);
        assert_eq!(t.at(1, 0), 54.0);
        assert_eq!(t.at(1, 1), 55.0);
        assert_eq!(ub.weight_reads, 4);
        assert_eq!(wf.words_fetched, 4);
        assert_eq!(WeightFetcher::load_cycles(&t), 2);
    }
}
