//! The Systolic Data Setup unit: per-row input FIFOs that skew activation
//! rows so the wavefront requirement of the array holds (element d of a row
//! enters PE row d exactly d cycles after element 0 enters row 0).
//!
//! In the functional emulator the skew is what determines pass timing; the
//! FIFO model here verifies the waveform property itself and is exercised
//! by the array's streaming loop.

/// One skewing FIFO bank for an array of `height` rows.
#[derive(Debug)]
pub struct SystolicDataSetup {
    height: usize,
    /// fifos[d] holds (enter_cycle, value) pairs not yet consumed.
    fifos: Vec<std::collections::VecDeque<(u64, f32)>>,
    pub pushes: u64,
    pub pops: u64,
}

impl SystolicDataSetup {
    pub fn new(height: usize) -> SystolicDataSetup {
        SystolicDataSetup {
            height,
            fifos: (0..height).map(|_| std::collections::VecDeque::new()).collect(),
            pushes: 0,
            pops: 0,
        }
    }

    pub fn height(&self) -> usize {
        self.height
    }

    /// Stage a full activation row (length `k_t <= height`) that begins
    /// entering the array at `base_cycle`: element d is scheduled for
    /// `base_cycle + d` — the diagonal waveform.
    pub fn stage_row(&mut self, base_cycle: u64, row: &[f32]) {
        assert!(row.len() <= self.height, "row longer than array height");
        for (d, &v) in row.iter().enumerate() {
            self.fifos[d].push_back((base_cycle + d as u64, v));
            self.pushes += 1;
        }
    }

    /// Pop the value entering PE row `d` at `cycle`, if its time has come.
    pub fn pop_if_due(&mut self, d: usize, cycle: u64) -> Option<f32> {
        if let Some(&(due, v)) = self.fifos[d].front() {
            if due == cycle {
                self.fifos[d].pop_front();
                self.pops += 1;
                return Some(v);
            }
            assert!(due > cycle, "FIFO {d} missed its slot: due {due}, now {cycle}");
        }
        None
    }

    /// Maximum staged depth across FIFOs (for FIFO sizing reports).
    pub fn max_depth(&self) -> usize {
        self.fifos.iter().map(|f| f.len()).max().unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.fifos.iter().all(|f| f.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waveform_skew() {
        let mut sds = SystolicDataSetup::new(4);
        sds.stage_row(10, &[1.0, 2.0, 3.0]);
        // Element d due at 10 + d.
        assert_eq!(sds.pop_if_due(0, 10), Some(1.0));
        assert_eq!(sds.pop_if_due(1, 10), None);
        assert_eq!(sds.pop_if_due(1, 11), Some(2.0));
        assert_eq!(sds.pop_if_due(2, 12), Some(3.0));
        assert!(sds.is_empty());
        assert_eq!(sds.pushes, 3);
        assert_eq!(sds.pops, 3);
    }

    #[test]
    fn consecutive_rows_pipeline() {
        let mut sds = SystolicDataSetup::new(2);
        sds.stage_row(0, &[1.0, 2.0]);
        sds.stage_row(1, &[3.0, 4.0]);
        // Row 0 of the array sees 1.0 then 3.0 on consecutive cycles.
        assert_eq!(sds.pop_if_due(0, 0), Some(1.0));
        assert_eq!(sds.pop_if_due(0, 1), Some(3.0));
        // Row 1 sees 2.0 at t=1 and 4.0 at t=2.
        assert_eq!(sds.pop_if_due(1, 1), Some(2.0));
        assert_eq!(sds.pop_if_due(1, 2), Some(4.0));
        assert_eq!(sds.max_depth(), 0);
    }

    #[test]
    #[should_panic(expected = "missed its slot")]
    fn missed_slot_is_a_bug() {
        let mut sds = SystolicDataSetup::new(1);
        sds.stage_row(5, &[1.0]);
        let _ = sds.pop_if_due(0, 6);
    }

    #[test]
    #[should_panic(expected = "longer than array height")]
    fn oversized_row_rejected() {
        let mut sds = SystolicDataSetup::new(2);
        sds.stage_row(0, &[1.0, 2.0, 3.0]);
    }
}
