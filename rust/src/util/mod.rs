//! Shared infrastructure: deterministic PRNG, statistics, JSON/CSV
//! serialization, logging, and the property-test mini-harness.
//!
//! These exist in-tree because the build environment is fully offline:
//! only minimal `anyhow`/`log` shims are vendored under `rust/vendor/`,
//! and the `xla`-backed PJRT bridge is feature-gated (see DESIGN.md §6).

pub mod bench;
pub mod csv;
pub mod json;
pub mod logging;
pub mod prng;
pub mod propcheck;
pub mod stats;

/// Integer ceiling division. Used throughout the tiling math.
#[inline]
pub const fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Human-readable large-number formatting (`12_345_678` → `"12.35M"`).
pub fn human_count(x: u64) -> String {
    let xf = x as f64;
    if xf >= 1e12 {
        format!("{:.2}T", xf / 1e12)
    } else if xf >= 1e9 {
        format!("{:.2}G", xf / 1e9)
    } else if xf >= 1e6 {
        format!("{:.2}M", xf / 1e6)
    } else if xf >= 1e3 {
        format!("{:.2}k", xf / 1e3)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        assert_eq!(ceil_div(8, 4), 2);
    }

    #[test]
    fn human_count_scales() {
        assert_eq!(human_count(17), "17");
        assert_eq!(human_count(1_500), "1.50k");
        assert_eq!(human_count(2_000_000), "2.00M");
        assert_eq!(human_count(3_100_000_000), "3.10G");
        assert_eq!(human_count(4_200_000_000_000), "4.20T");
    }
}
