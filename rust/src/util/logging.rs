//! A minimal `log`-crate backend writing to stderr with wall-clock-relative
//! timestamps. Installed once by the CLI / examples via [`init`].

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:>8.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the stderr logger at the given verbosity. Idempotent.
pub fn init(level: LevelFilter) {
    let logger = LOGGER.get_or_init(|| StderrLogger {
        start: Instant::now(),
    });
    // set_logger fails if already installed — that is fine.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

/// Parse `-q`/`-v`/`-vv` style verbosity into a level filter.
pub fn level_from_verbosity(quiet: bool, verbose: u8) -> LevelFilter {
    if quiet {
        LevelFilter::Error
    } else {
        match verbose {
            0 => LevelFilter::Info,
            1 => LevelFilter::Debug,
            _ => LevelFilter::Trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verbosity_mapping() {
        assert_eq!(level_from_verbosity(true, 5), LevelFilter::Error);
        assert_eq!(level_from_verbosity(false, 0), LevelFilter::Info);
        assert_eq!(level_from_verbosity(false, 1), LevelFilter::Debug);
        assert_eq!(level_from_verbosity(false, 2), LevelFilter::Trace);
    }

    #[test]
    fn init_is_idempotent() {
        init(LevelFilter::Info);
        init(LevelFilter::Debug);
        log::info!("logging smoke test");
    }
}
