//! Minimal JSON value model with a serializer and a strict parser.
//!
//! The offline environment ships no serde facade crate, so CAMUY keeps a
//! purpose-built implementation. It covers everything the tool emits or
//! reads (sweep dumps, config files, experiment manifests): objects,
//! arrays, strings, finite numbers, booleans and null — no extensions.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Optional non-negative integer field: `Ok(None)` when absent, an
    /// error naming the key when present but malformed (a wire surface
    /// must not silently substitute defaults for typo'd fields). The one
    /// optional-field parser every ingestion path shares.
    pub fn opt_usize_field(&self, key: &str) -> Result<Option<usize>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(j) => j
                .as_usize()
                .map(Some)
                .ok_or_else(|| format!("field '{key}' must be a non-negative integer")),
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_number(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed).
    /// Nesting is bounded (128 levels) so untrusted input cannot overflow
    /// the stack of the recursive-descent parser.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(out: &mut String, x: f64) {
    assert!(x.is_finite(), "JSON cannot represent non-finite number");
    if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Most container levels a document may nest; recursion depth is bounded
/// by this, keeping hostile `[[[[…` input from overflowing the stack.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting depth exceeds the limit"));
        }
        Ok(())
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        let r = self.array_inner();
        self.depth -= 1;
        r
    }

    fn array_inner(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.skip_ws();
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => self.skip_ws(),
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        let r = self.object_inner();
        self.depth -= 1;
        r
    }

    fn object_inner(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.skip_ws();
        let mut map = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => {}
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let code = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let low = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&low) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(code).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let len = if b >= 0xF0 {
                            4
                        } else if b >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_compact() {
        let v = Json::obj(vec![
            ("name", Json::str("resnet152")),
            ("cycles", Json::num(123456.0)),
            ("ok", Json::Bool(true)),
            ("tags", Json::arr(vec![Json::str("a"), Json::Null])),
        ]);
        let text = v.to_string_compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![("x", Json::arr(vec![Json::num(1.0), Json::num(2.5)]))]);
        let back = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"\\A""#).unwrap();
        assert_eq!(v, Json::Str("a\nb\t\"\\A".to_string()));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v, Json::Str("😀".to_string()));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo — ∑\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ∑");
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("-12.5e2").unwrap().as_f64().unwrap(), -1250.0);
        assert_eq!(Json::parse("0").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn nesting_depth_is_bounded() {
        // A hostile megabyte of '[' must error, not overflow the stack.
        let hostile = "[".repeat(1 << 20);
        assert!(Json::parse(&hostile).is_err());
        // Exactly at the limit parses; one past it does not.
        let ok = format!("{}{}", "[".repeat(128), "]".repeat(128));
        assert!(Json::parse(&ok).is_ok());
        let too_deep = format!("{}{}", "[".repeat(129), "]".repeat(129));
        assert!(Json::parse(&too_deep).is_err());
        // Depth is nesting, not total container count: wide-and-shallow
        // documents of any length are fine.
        let wide = format!("[{}]", vec!["[1]"; 10_000].join(","));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn opt_usize_field_defaults_absent_but_rejects_malformed() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "f": 2.5, "neg": -1}"#).unwrap();
        assert_eq!(v.opt_usize_field("n").unwrap(), Some(3));
        assert_eq!(v.opt_usize_field("missing").unwrap(), None);
        for present_but_bad in ["s", "f", "neg"] {
            assert!(v.opt_usize_field(present_but_bad).is_err());
        }
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(42.0).to_string_compact(), "42");
        assert_eq!(Json::num(2.5).to_string_compact(), "2.5");
    }
}
