//! A small property-based testing harness (the offline build has no
//! proptest). Tests draw cases from a deterministic [`Rng`], run a checker
//! returning `Result<(), String>`, and on failure attempt greedy shrinking
//! of the failing case before reporting.
//!
//! Usage:
//! ```ignore
//! check(1000, 0xC0FFEE, |rng| Case::random(rng), |case| {
//!     if bad(case) { Err(format!("violated: {case:?}")) } else { Ok(()) }
//! });
//! ```

use crate::util::prng::Rng;
use std::fmt::Debug;

/// A generated case that knows how to propose smaller versions of itself.
pub trait Shrink: Sized + Clone {
    /// Candidate strictly-smaller cases, most aggressive first. Default: none.
    fn shrink_candidates(&self) -> Vec<Self> {
        Vec::new()
    }
}

/// Run `iters` random trials. Panics with the (shrunk) counterexample and
/// the reproducing seed on failure.
pub fn check<T, G, F>(iters: usize, seed: u64, mut generate: G, mut property: F)
where
    T: Shrink + Debug,
    G: FnMut(&mut Rng) -> T,
    F: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for i in 0..iters {
        let case = generate(&mut rng);
        if let Err(msg) = property(&case) {
            let (min_case, min_msg, steps) = shrink_loop(case, msg, &mut property);
            panic!(
                "property failed (seed={seed}, iter={i}, shrink_steps={steps}):\n  \
                 case: {min_case:?}\n  error: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T, F>(mut case: T, mut msg: String, property: &mut F) -> (T, String, usize)
where
    T: Shrink + Debug,
    F: FnMut(&T) -> Result<(), String>,
{
    let mut steps = 0;
    // Bounded greedy descent: take the first still-failing candidate.
    'outer: for _ in 0..10_000 {
        for cand in case.shrink_candidates() {
            if let Err(m) = property(&cand) {
                case = cand;
                msg = m;
                steps += 1;
                continue 'outer;
            }
        }
        break;
    }
    (case, msg, steps)
}

/// Shrinking helper for usize fields: halving ladder toward `lo`.
pub fn shrink_usize(x: usize, lo: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if x > lo {
        out.push(lo);
        let mid = lo + (x - lo) / 2;
        if mid != lo && mid != x {
            out.push(mid);
        }
        if x - 1 != lo && x - 1 != mid {
            out.push(x - 1);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone)]
    struct Pair {
        a: usize,
        b: usize,
    }

    impl Shrink for Pair {
        fn shrink_candidates(&self) -> Vec<Self> {
            let mut cands = Vec::new();
            for a in shrink_usize(self.a, 0) {
                cands.push(Pair { a, b: self.b });
            }
            for b in shrink_usize(self.b, 0) {
                cands.push(Pair { a: self.a, b });
            }
            cands
        }
    }

    #[test]
    fn passing_property_passes() {
        check(
            500,
            1,
            |rng| Pair {
                a: rng.range_usize(0, 100),
                b: rng.range_usize(0, 100),
            },
            |p| {
                if p.a + p.b >= p.a {
                    Ok(())
                } else {
                    Err("impossible".into())
                }
            },
        );
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            check(
                500,
                2,
                |rng| Pair {
                    a: rng.range_usize(0, 1000),
                    b: rng.range_usize(0, 1000),
                },
                |p| {
                    // Fails whenever a >= 100; minimal counterexample a=100.
                    if p.a < 100 {
                        Ok(())
                    } else {
                        Err(format!("a too big: {}", p.a))
                    }
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // The greedy shrinker must land on the boundary case a=100.
        assert!(msg.contains("a: 100"), "unshrunk failure: {msg}");
    }

    #[test]
    fn shrink_usize_ladder() {
        assert_eq!(shrink_usize(0, 0), Vec::<usize>::new());
        assert_eq!(shrink_usize(1, 0), vec![0]);
        let c = shrink_usize(100, 1);
        assert!(c.contains(&1) && c.contains(&50) && c.contains(&99));
    }
}
