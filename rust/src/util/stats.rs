//! Small statistics helpers shared by the sweep engine, the bench harness
//! and the reports: summaries, normalization, and percentile estimation.

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub stddev: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Some(Summary {
            n,
            min: sorted[0],
            max: sorted[n - 1],
            mean,
            stddev: var.sqrt(),
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        })
    }
}

/// Percentile (linear interpolation) of an already sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Min-max normalize a slice into `[0, 1]`. Constant slices map to all 0.
/// This is the normalization the paper applies per-model before averaging
/// across models (Section 5).
pub fn min_max_normalize(xs: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return Vec::new();
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    let span = hi - lo;
    if span <= 0.0 {
        return vec![0.0; xs.len()];
    }
    xs.iter().map(|x| (x - lo) / span).collect()
}

/// Geometric mean; requires strictly positive entries.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 4.0);
        assert!((percentile_sorted(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn normalize_unit_interval() {
        let n = min_max_normalize(&[10.0, 20.0, 15.0]);
        assert_eq!(n, vec![0.0, 1.0, 0.5]);
    }

    #[test]
    fn normalize_constant_is_zero() {
        assert_eq!(min_max_normalize(&[4.0, 4.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0, 16.0]) - 4.0).abs() < 1e-9);
    }
}
