//! A tiny benchmark harness (the offline environment has no criterion):
//! warmup + timed iterations, robust summary statistics, and the
//! criterion-style one-line report the `cargo bench` targets print.

use crate::util::stats::Summary;
use std::hint::black_box;
use std::time::Instant;

/// Configuration for one measured benchmark.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub measure_iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup_iters: 2,
            measure_iters: 10,
        }
    }
}

impl BenchOpts {
    /// Scale iteration counts for long-running benches.
    pub fn slow() -> BenchOpts {
        BenchOpts {
            warmup_iters: 1,
            measure_iters: 3,
        }
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub seconds: Summary,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12} /iter  (min {:>10}, p95 {:>10}, n={})",
            self.name,
            fmt_duration(self.seconds.mean),
            fmt_duration(self.seconds.min),
            fmt_duration(self.seconds.p95),
            self.seconds.n
        )
    }
}

/// Format seconds human-readably.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Measure `f`, printing a criterion-style line. The closure's return value
/// is black-boxed so the work is not optimized away.
pub fn bench<T>(name: &str, opts: &BenchOpts, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(opts.measure_iters);
    for _ in 0..opts.measure_iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let result = BenchResult {
        name: name.to_string(),
        seconds: Summary::of(&samples).expect("non-empty"),
    };
    println!("{}", result.report_line());
    result
}

/// Throughput helper: items per second from a result.
pub fn throughput(result: &BenchResult, items: u64) -> f64 {
    items as f64 / result.seconds.mean
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench(
            "spin",
            &BenchOpts {
                warmup_iters: 1,
                measure_iters: 5,
            },
            || (0..10_000u64).sum::<u64>(),
        );
        assert_eq!(r.seconds.n, 5);
        assert!(r.seconds.mean > 0.0);
        assert!(throughput(&r, 10_000) > 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.0), "2.000 s");
        assert_eq!(fmt_duration(0.0015), "1.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert_eq!(fmt_duration(3e-9), "3.0 ns");
    }
}
