//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so CAMUY carries a
//! small, well-understood generator of its own: SplitMix64 (Steele et al.,
//! "Fast splittable pseudorandom number generators", OOPSLA 2014) for
//! seeding and a xoshiro256**-style core for the main stream. Determinism
//! matters here: NSGA-II runs, property tests and workload generators must
//! be exactly reproducible from a printed seed.

/// SplitMix64: used to expand a user seed into stream state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Main PRNG: xoshiro256** (Blackman & Vigna). 64-bit output, period 2^256-1.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a seed; the seed is expanded through SplitMix64 as the
    /// xoshiro authors recommend (avoids all-zero and low-entropy states).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection method.
    #[inline]
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let low = m as u64;
            if low >= bound || low >= low.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.next_bounded((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`: top 53 bits of the stream.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_bounded((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.next_bounded(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (from the public-domain C code).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism across constructions.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn bounded_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.next_bounded(37) < 37);
        }
    }

    #[test]
    fn range_usize_inclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_usize(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_is_near_half() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
