//! CSV writing (RFC 4180 quoting) for sweep results and figure data.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// An in-memory CSV table with a fixed header.
#[derive(Debug, Clone)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Push a row; panics if the arity does not match the header (these
    /// tables feed plotting scripts, a ragged row is always a bug).
    pub fn push<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "CSV row arity {} != header arity {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_record(&mut out, &self.header);
        for row in &self.rows {
            write_record(&mut out, row);
        }
        out
    }

    pub fn write_to<P: AsRef<Path>>(&self, path: P) -> io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_string())
    }
}

fn write_record(out: &mut String, fields: &[String]) {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if f.contains(',') || f.contains('"') || f.contains('\n') {
            out.push('"');
            out.push_str(&f.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(f);
        }
    }
    out.push('\n');
}

/// Format an f64 for CSV with enough digits to round-trip sweeps, but
/// without noise for integral values.
pub fn fmt_f64(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        let mut s = String::new();
        let _ = write!(s, "{x:.6}");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_table() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.push(vec!["1", "2"]);
        t.push(vec!["x,y", "q\"z"]);
        let s = t.to_string();
        assert_eq!(s, "a,b\n1,2\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn ragged_row_panics() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.push(vec!["only-one"]);
    }

    #[test]
    fn fmt_integral() {
        assert_eq!(fmt_f64(42.0), "42");
        assert_eq!(fmt_f64(0.5), "0.500000");
    }
}
