//! `camuy` CLI — see `camuy --help` / rust/src/cli/mod.rs.

fn main() {
    // Restore default SIGPIPE behaviour so `camuy ... | head` terminates
    // quietly instead of panicking on a closed stdout.
    #[cfg(unix)]
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(camuy::cli::run(&argv));
}
