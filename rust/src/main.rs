//! `camuy` CLI — see `camuy --help` / rust/src/cli/mod.rs.

fn main() {
    // Restore default SIGPIPE behaviour so `camuy ... | head` terminates
    // quietly instead of panicking on a closed stdout. Raw syscall shim:
    // the offline image ships no `libc` crate (DESIGN.md §6).
    #[cfg(unix)]
    {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGPIPE: i32 = 13;
        const SIG_DFL: usize = 0;
        unsafe {
            signal(SIGPIPE, SIG_DFL);
        }
    }
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(camuy::cli::run(&argv));
}
