//! Offline stand-in for the `log` facade crate.
//!
//! The build environment ships no crates.io registry, so CAMUY vendors the
//! subset of the real crate it uses (DESIGN.md §6): the [`Log`] trait,
//! [`Level`]/[`LevelFilter`], record/metadata types, the global logger
//! registry, and the five level macros. Semantics mirror the real crate:
//! records above [`max_level`] are discarded, and [`set_logger`] accepts
//! the first logger for the lifetime of the process.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single record, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Global verbosity ceiling; `Off` disables everything.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        })
    }
}

/// Metadata of a record: its level and target (module path by default).
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record handed to the installed [`Log`] backend.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the process-wide logger; fails if one is already set.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// The current global verbosity ceiling.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if level > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record {
            metadata: Metadata { level, target },
            args,
        };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Error, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Trace, module_path!(), format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_orderings_cross_compare() {
        assert!(Level::Error <= LevelFilter::Error);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(LevelFilter::Trace >= Level::Trace);
        assert!(Level::Info < LevelFilter::Trace);
    }

    #[test]
    fn max_level_roundtrips() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }

    #[test]
    fn logging_without_logger_is_a_noop() {
        set_max_level(LevelFilter::Trace);
        info!("nobody is listening: {}", 1);
        set_max_level(LevelFilter::Off);
    }
}
