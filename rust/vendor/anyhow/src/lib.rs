//! Offline stand-in for the `anyhow` error facade.
//!
//! The build environment ships no crates.io registry, so CAMUY vendors the
//! small subset of the real crate's API it actually uses (DESIGN.md §6):
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error values carry a flat
//! context chain; `{}` displays the outermost message and `{:#}` the whole
//! chain, mirroring the real crate's formatting contract.

use std::fmt;

/// `Result` defaulted to [`Error`], as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost message plus the chain of causes that
/// produced it (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

fn chain_of(err: &(dyn std::error::Error + 'static)) -> Vec<String> {
    let mut chain = vec![err.to_string()];
    let mut source = err.source();
    while let Some(s) = source {
        chain.push(s.to_string());
        source = s.source();
    }
    chain
}

// Mirrors the real crate: any std error converts via `?`, preserving its
// source chain. `Error` itself deliberately does not implement
// `std::error::Error`, which keeps this blanket impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error {
            chain: chain_of(&err),
        }
    }
}

/// Extension trait attaching context to fallible values.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T, Error>;
}

// As in the real crate, contextualizing a std error preserves its whole
// source chain, not just its top-level Display.
impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context()))
    }
}

// Contextualizing an already-wrapped `Error` extends its existing chain.
// Coherent with the impl above because `Error` is a local type that does
// not implement `std::error::Error`.
impl<T> Context<T, Error> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T, Error> {
        self.map_err(|e| e.context(context()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e)?;
        Ok(())
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(format!("{e}").contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "boom"));
        let e = r.context("doing a thing").unwrap_err();
        assert_eq!(format!("{e:#}"), "doing a thing: boom");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn context_preserves_source_chains() {
        #[derive(Debug)]
        struct Leaf;
        impl fmt::Display for Leaf {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.write_str("root cause")
            }
        }
        impl std::error::Error for Leaf {}

        #[derive(Debug)]
        struct Mid(Leaf);
        impl fmt::Display for Mid {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.write_str("mid-level failure")
            }
        }
        impl std::error::Error for Mid {
            fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
                Some(&self.0)
            }
        }

        let r: std::result::Result<(), Mid> = Err(Mid(Leaf));
        let e = r.context("outer").unwrap_err();
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "mid-level failure", "root cause"]);
        // Contextualizing an Error again keeps extending the same chain.
        let r2: Result<()> = Err(e);
        let e2 = r2.context("outermost").unwrap_err();
        assert_eq!(e2.chain().count(), 4);
        assert_eq!(format!("{e2}"), "outermost");
        assert_eq!(
            format!("{e2:#}"),
            "outermost: outer: mid-level failure: root cause"
        );
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn ensure_without_message_names_the_condition() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x % 2 == 0);
            Ok(x)
        }
        assert_eq!(f(4).unwrap(), 4);
        assert_eq!(
            format!("{}", f(3).unwrap_err()),
            "Condition failed: `x % 2 == 0`"
        );
    }
}
