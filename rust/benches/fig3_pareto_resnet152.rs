//! Bench FIG3: NSGA-II Pareto extraction for ResNet-152 (both objective
//! pairs), including the underlying sweep, vs. the exhaustive front.

use camuy::pareto::nsga2::Nsga2Params;
use camuy::report::figures::{fig3_pareto, FigureContext};
use camuy::util::bench::{bench, BenchOpts};

fn main() {
    let ctx = FigureContext::paper();
    let params = Nsga2Params::default();
    println!(
        "== FIG3: ResNet-152 Pareto (NSGA-II pop={} gen={}) ==",
        params.population, params.generations
    );
    bench("fig3/nsga2_both_objectives", &BenchOpts::default(), || {
        fig3_pareto("resnet152", &ctx, &params)
    });

    let data = fig3_pareto("resnet152", &ctx, &params);
    println!(
        "   energy front: NSGA-II {} pts / exhaustive {} pts",
        data.energy_front.len(),
        data.exhaustive_energy_front.len()
    );
    println!(
        "   utilization front: NSGA-II {} pts / exhaustive {} pts",
        data.utilization_front.len(),
        data.exhaustive_utilization_front.len()
    );
    // Paper-style annotation dump of the energy front.
    for s in data.energy_front.iter().take(8) {
        println!(
            "   ({:>3}, {:>3})  E {:.4e}  cycles {:.4e}",
            s.height, s.width, s.objectives[0], s.objectives[1]
        );
    }
}
