//! Ablation benches for the design choices DESIGN.md §3.1 calls out:
//!
//! * accumulator capacity (the width-penalty mechanism) — E vs C_acc;
//! * dataflow: weight-stationary vs output-stationary (paper §6 future
//!   work) on CNNs and transformers;
//! * energy model: paper Eq. 1 weights vs Dally-et-al. 14 nm re-weighting
//!   — does the tall-narrow recommendation survive technology scaling?
//! * double buffering: CAMUY vs the SCALE-SIM-style exposed-load baseline.

use camuy::baseline::scalesim_metrics;
use camuy::config::{ArrayConfig, Dataflow, EnergyWeights};
use camuy::nets;
use camuy::sweep::grid::DimGrid;
use camuy::sweep::runner::{sweep_network, Workload};
use camuy::util::bench::{bench, BenchOpts};

fn main() {
    println!("== ablation: accumulator capacity (ResNet-152, 64x64) ==");
    let net = nets::build("resnet152").unwrap();
    let wl = Workload::of(&net);
    for acc in [256usize, 1024, 4096, 16384, 1 << 20] {
        let cfg = ArrayConfig::new(64, 64).with_acc_capacity(acc);
        let m = wl.eval(&cfg);
        println!(
            "   C_acc {:>8}: E {:.4e}, UB weight reads {:.3e}, cycles {:.3e}",
            acc,
            m.energy(&EnergyWeights::paper()),
            m.movements.ub_weight_reads as f64,
            m.cycles as f64
        );
    }

    println!("\n== ablation: dataflow (ws vs os) ==");
    for name in ["resnet152", "mobilenetv3l", "bertbase-s128"] {
        let net = nets::build(name).unwrap();
        let wl = Workload::of(&net);
        let ws = wl.eval(&ArrayConfig::new(64, 64));
        let os = wl.eval(&ArrayConfig::new(64, 64).with_dataflow(Dataflow::OutputStationary));
        println!(
            "   {:<14} E(ws) {:.3e}  E(os) {:.3e}  cycles(ws) {:.3e}  cycles(os) {:.3e}",
            name,
            ws.energy(&EnergyWeights::paper()),
            os.energy(&EnergyWeights::paper()),
            ws.cycles as f64,
            os.cycles as f64
        );
    }

    println!("\n== ablation: technology scaling of Equation 1 ==");
    // Does the optimal (height, width) move under 14nm weights?
    let grid = DimGrid::paper();
    let cfgs = grid.configs(&ArrayConfig::new(1, 1));
    for (label, w) in [
        ("paper", EnergyWeights::paper()),
        ("dally14nm", EnergyWeights::dally_14nm()),
    ] {
        let sweep = sweep_network(&net, &cfgs, &w, camuy::sweep::runner::default_threads());
        let best = sweep.argmin(|p| p.energy).expect("non-empty sweep");
        println!(
            "   {:<10} best (h, w) = ({:>3}, {:>3}), E {:.4e}",
            label, best.height, best.width, best.energy
        );
    }

    println!("\n== ablation: cycle model vs SCALE-SIM-style baseline ==");
    // The two models differ in three places: CAMUY hides weight loads
    // (double buffering) but pays full-height drains and accumulator
    // chunking; SCALE-SIM exposes every load but assumes an infinite
    // accumulator. Separate the effects by also running CAMUY with an
    // effectively infinite accumulator.
    for (label, acc) in [("acc=4096", 4096usize), ("acc=inf", 1 << 30)] {
        let cfg = ArrayConfig::new(128, 128).with_acc_capacity(acc);
        let camuy_total: u64 = net.layers.iter().map(|l| l.metrics(&cfg).cycles).sum();
        let scalesim_total: u64 = net
            .layers
            .iter()
            .map(|l| {
                let (g, groups) = l.gemm();
                scalesim_metrics(g, &cfg).cycles * groups as u64
            })
            .sum();
        println!(
            "   ResNet-152 @128x128 {label}: CAMUY {camuy_total} vs SCALE-SIM-style \
             {scalesim_total} cycles (ratio {:.2})",
            camuy_total as f64 / scalesim_total as f64
        );
    }

    println!("\n== ablation: multi-array scaling (paper §6 future work) ==");
    for name in ["resnet152", "resnext152", "mobilenetv3l"] {
        let n = nets::build(name).unwrap();
        let base = camuy::model::multi::network_metrics_multi(
            &n,
            &camuy::model::multi::MultiArrayConfig::new(1, ArrayConfig::new(64, 64)),
        );
        print!("   {name:<14}");
        for arrays in [2usize, 4, 8] {
            let m = camuy::model::multi::network_metrics_multi(
                &n,
                &camuy::model::multi::MultiArrayConfig::new(arrays, ArrayConfig::new(64, 64)),
            );
            print!(
                "  {arrays}x: {:.2}x speedup {:+.1}% E",
                base.makespan_cycles as f64 / m.makespan_cycles as f64,
                100.0
                    * (m.energy(&EnergyWeights::paper()) / base.energy(&EnergyWeights::paper())
                        - 1.0)
            );
        }
        println!();
    }

    println!("\n== ablation timing ==");
    bench("ablation/acc_capacity_sweep", &BenchOpts::default(), || {
        [256usize, 1024, 4096, 16384]
            .iter()
            .map(|&acc| {
                wl.eval(&ArrayConfig::new(64, 64).with_acc_capacity(acc))
                    .cycles
            })
            .sum::<u64>()
    });
}
