//! Graph-IR benchmarks (DESIGN.md §9), emitted machine-readably to
//! `BENCH_graph.json` (override the path with `CAMUY_GRAPH_BENCH_OUT`):
//!
//! * chain-lowering overhead — evaluating a zoo model through the DAG IR
//!   vs the flat `Vec<Layer>` path (must be near-free);
//! * liveness-pass throughput over the full registry (graphs/s, nodes/s);
//! * branch-parallel makespans on 1/2/4-array banks for GoogLeNet and
//!   DenseNet-201, against the serialized baseline.

use camuy::config::ArrayConfig;
use camuy::model::graph::NetworkGraph;
use camuy::model::multi::MultiArrayConfig;
use camuy::model::workload::EvalCache;
use camuy::nets;
use camuy::util::bench::{bench, throughput, BenchOpts};
use camuy::util::json::Json;

fn main() {
    let cfg = ArrayConfig::new(128, 128);
    let opts = BenchOpts {
        warmup_iters: 3,
        measure_iters: 20,
    };

    println!("== graph: chain-lowering overhead ==");
    let net = nets::build("densenet201").unwrap();
    let flat = bench("graph/flat_eval_densenet201", &opts, || net.metrics(&cfg));
    let chain = NetworkGraph::chain(&net);
    let lowered = bench("graph/chain_lowered_eval_densenet201", &opts, || {
        chain.metrics(&cfg)
    });
    let overhead = lowered.seconds.mean / flat.seconds.mean;
    println!("   -> chain lowering costs {overhead:.2}x the flat evaluation");

    println!("\n== graph: liveness pass over the full zoo ==");
    let graphs: Vec<NetworkGraph> = nets::ALL_MODELS
        .iter()
        .map(|n| nets::build_graph(n).expect("registered"))
        .collect();
    let total_nodes: u64 = graphs.iter().map(|g| g.len() as u64).sum();
    let live = bench("graph/liveness_full_zoo", &opts, || {
        graphs
            .iter()
            .map(|g| g.liveness(&cfg).peak_bytes)
            .sum::<u64>()
    });
    let graphs_per_sec = throughput(&live, graphs.len() as u64);
    let nodes_per_sec = throughput(&live, total_nodes);
    println!(
        "   -> {graphs_per_sec:.0} liveness passes/s ({nodes_per_sec:.0} nodes/s over {} graphs, {total_nodes} nodes)",
        graphs.len()
    );

    println!("\n== graph: branch-parallel makespan (googlenet, densenet201) ==");
    let cache = EvalCache::new();
    let mut sched_json: Vec<Json> = Vec::new();
    for name in ["googlenet", "densenet201"] {
        let g = nets::build_graph(name).unwrap();
        for arrays in [1usize, 2, 4] {
            let bank = MultiArrayConfig::new(arrays, cfg.clone());
            let r = bench(
                &format!("graph/schedule_{name}_{arrays}arrays"),
                &opts,
                || g.schedule(&bank, &cache).makespan_cycles,
            );
            let s = g.schedule(&bank, &cache);
            println!(
                "   -> {name} on {arrays} array(s): makespan {} / serialized {} (speedup {:.2}x, critical path {})",
                s.makespan_cycles,
                s.serialized_cycles,
                s.speedup(),
                s.critical_path_cycles
            );
            sched_json.push(Json::obj(vec![
                ("network", Json::str(name)),
                ("arrays", Json::num(arrays as f64)),
                ("makespan_cycles", Json::num(s.makespan_cycles as f64)),
                ("serialized_cycles", Json::num(s.serialized_cycles as f64)),
                (
                    "critical_path_cycles",
                    Json::num(s.critical_path_cycles as f64),
                ),
                ("speedup", Json::num(s.speedup())),
                ("seconds_mean", Json::num(r.seconds.mean)),
            ]));
        }
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("graph_liveness")),
        ("models", Json::num(graphs.len() as f64)),
        ("total_nodes", Json::num(total_nodes as f64)),
        (
            "chain_lowering_overhead_x",
            Json::num(overhead),
        ),
        ("flat_eval_seconds_mean", Json::num(flat.seconds.mean)),
        (
            "chain_eval_seconds_mean",
            Json::num(lowered.seconds.mean),
        ),
        ("liveness_passes_per_sec", Json::num(graphs_per_sec)),
        ("liveness_nodes_per_sec", Json::num(nodes_per_sec)),
        ("schedules", Json::arr(sched_json)),
    ]);
    let out_path =
        std::env::var("CAMUY_GRAPH_BENCH_OUT").unwrap_or_else(|_| "BENCH_graph.json".into());
    match std::fs::write(&out_path, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("\n   -> wrote {out_path}"),
        Err(e) => eprintln!("\n   -> could not write {out_path}: {e}"),
    }
}
