//! Event-driven simulator benchmarks (DESIGN.md §13), emitted
//! machine-readably to `BENCH_trace.json` (override the path with
//! `CAMUY_TRACE_BENCH_OUT`):
//!
//! * event throughput — queue events processed per second over a full
//!   zoo network's tiling schedule, both dataflows;
//! * sim-vs-analytic slowdown — the cost of *executing* the machine
//!   instead of evaluating the closed forms it is property-tested
//!   against (the price of the second oracle);
//! * trace-on vs trace-off overhead — what recording Perfetto slices
//!   and counters costs relative to the `TraceSink::Off` zero-cost path.
//!
//! `CAMUY_BENCH_SMOKE=1` is the CI gate: the process fails (exit 1) if
//! the trace-on overhead or the sim-vs-analytic slowdown exceeds its
//! generous structural bound — both ratios are best-over-best, so a
//! loaded runner cannot flake a regression-free commit red.

use camuy::config::{ArrayConfig, Dataflow};
use camuy::model::workload::Workload;
use camuy::nets;
use camuy::sim::{simulate_network, SimOptions};
use camuy::util::bench::{bench, throughput, BenchOpts};
use camuy::util::json::Json;

/// Trace-on may cost at most this much over trace-off (best-over-best).
/// Recording a slice is a push plus a closure call; even with string
/// formatting the traced run stays within a small constant of the plain
/// one — far under this bound unless the zero-cost path regresses.
const MAX_TRACE_OVERHEAD: f64 = 50.0;

/// The simulator may cost at most this much over the analytic closed
/// forms (best-over-best). The analytic path is a few hundred
/// nanoseconds per distinct shape; executing the event machine is
/// inherently orders of magnitude more — the bound only catches a
/// pathological regression (e.g. the queue losing its O(log n) pop).
const MAX_SIM_SLOWDOWN: f64 = 200_000.0;

fn main() {
    let smoke = std::env::var("CAMUY_BENCH_SMOKE")
        .map(|v| v == "1")
        .unwrap_or(false);
    let opts = if smoke {
        BenchOpts {
            warmup_iters: 1,
            measure_iters: 3,
        }
    } else {
        BenchOpts::default()
    };

    let net = nets::build("alexnet").unwrap();
    let cfg = ArrayConfig::new(32, 32);
    let os_cfg = ArrayConfig::new(32, 32).with_dataflow(Dataflow::OutputStationary);

    println!("== sim: event throughput (alexnet, 32x32) ==");
    let probe = simulate_network(&net, &cfg, 1, &SimOptions::default());
    let off = bench("sim/alexnet_ws_untraced", &opts, || {
        simulate_network(&net, &cfg, 1, &SimOptions::default()).events
    });
    let events_per_sec = throughput(&off, probe.events);
    println!("   -> {events_per_sec:.0} events/s ({} events per run)", probe.events);

    let os_probe = simulate_network(&net, &os_cfg, 1, &SimOptions::default());
    let os_off = bench("sim/alexnet_os_untraced", &opts, || {
        simulate_network(&net, &os_cfg, 1, &SimOptions::default()).events
    });
    let os_events_per_sec = throughput(&os_off, os_probe.events);
    println!(
        "   -> {os_events_per_sec:.0} events/s OS ({} events per run)",
        os_probe.events
    );

    println!("\n== sim: slowdown over the analytic closed forms ==");
    let workload = Workload::of(&net);
    let analytic = bench("sim/alexnet_analytic", &opts, || {
        workload.eval(&cfg).cycles
    });
    let slowdown = off.seconds.mean / analytic.seconds.mean;
    let slowdown_best = off.seconds.min / analytic.seconds.min;
    println!(
        "   -> executing the machine costs {slowdown:.0}x the closed forms \
         (best-over-best {slowdown_best:.0}x)"
    );
    // The two oracles must agree — the slowdown is only worth paying
    // because the equality is exact (tests/property_sim.rs).
    assert_eq!(probe.total, workload.eval(&cfg), "sim diverged from analytic");

    println!("\n== sim: trace-on overhead over TraceSink::Off ==");
    let traced_probe = simulate_network(&net, &cfg, 1, &SimOptions::traced(1 << 16));
    let on = bench("sim/alexnet_ws_traced", &opts, || {
        simulate_network(&net, &cfg, 1, &SimOptions::traced(1 << 16)).events
    });
    let overhead = on.seconds.mean / off.seconds.mean;
    let overhead_best = on.seconds.min / off.seconds.min;
    println!(
        "   -> tracing costs {overhead:.2}x the untraced run \
         (best-over-best {overhead_best:.2}x, {} slices)",
        traced_probe.slice_count()
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("sim_trace")),
        ("network", Json::str("alexnet")),
        ("events_per_run", Json::num(probe.events as f64)),
        ("events_per_sec", Json::num(events_per_sec)),
        ("os_events_per_sec", Json::num(os_events_per_sec)),
        ("sim_seconds_mean", Json::num(off.seconds.mean)),
        ("analytic_seconds_mean", Json::num(analytic.seconds.mean)),
        ("slowdown_sim_over_analytic", Json::num(slowdown)),
        ("traced_seconds_mean", Json::num(on.seconds.mean)),
        ("overhead_trace_on_over_off", Json::num(overhead)),
        ("trace_slices", Json::num(traced_probe.slice_count() as f64)),
    ]);
    let out =
        std::env::var("CAMUY_TRACE_BENCH_OUT").unwrap_or_else(|_| "BENCH_trace.json".to_string());
    match std::fs::write(&out, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("\n   -> wrote {out}"),
        Err(e) => eprintln!("\n   -> could not write {out}: {e}"),
    }

    if smoke {
        if overhead_best > MAX_TRACE_OVERHEAD {
            eprintln!(
                "FAIL: trace-on costs {overhead_best:.2}x the untraced run \
                 best-over-best (bound {MAX_TRACE_OVERHEAD}x)"
            );
            std::process::exit(1);
        }
        if slowdown_best > MAX_SIM_SLOWDOWN {
            eprintln!(
                "FAIL: the simulator costs {slowdown_best:.0}x the analytic \
                 closed forms best-over-best (bound {MAX_SIM_SLOWDOWN}x)"
            );
            std::process::exit(1);
        }
        println!(
            "smoke gate passed: trace overhead {overhead_best:.2}x (bound \
             {MAX_TRACE_OVERHEAD}x), sim slowdown {slowdown_best:.0}x (bound \
             {MAX_SIM_SLOWDOWN}x)"
        );
    }
}
