//! Bench FIG5: the robustness analysis — nine full-grid sweeps,
//! per-model normalization, cross-model averaging, NSGA-II + exhaustive
//! Pareto extraction.

use camuy::pareto::nsga2::Nsga2Params;
use camuy::report::figures::{fig5_robust, FigureContext};
use camuy::util::bench::{bench, BenchOpts};

fn main() {
    let ctx = FigureContext::paper();
    println!("== FIG5: robust Pareto across the nine paper models ==");
    bench("fig5/robust_pareto_full", &BenchOpts::default(), || {
        fig5_robust(&ctx, &Nsga2Params::default())
    });

    let data = fig5_robust(&ctx, &Nsga2Params::default());
    println!("   front size: {} (exhaustive {})", data.front.len(), data.exhaustive_front.len());
    let tall = data
        .front
        .iter()
        .filter(|s| s.height > s.width)
        .count();
    println!(
        "   height > width on {}/{} front points (the paper's tall-narrow finding)",
        tall,
        data.front.len()
    );
}
