//! Bench FIG4: data-movement heatmaps for all nine paper models over the
//! full 961-configuration grid — the paper's headline "fast exploration"
//! workload (9 x 961 network sweeps).

use camuy::report::figures::{fig4_heatmaps, FigureContext};
use camuy::util::bench::{bench, throughput, BenchOpts};

fn main() {
    let ctx = FigureContext::paper();
    let total = 9 * ctx.grid.len() as u64;
    println!("== FIG4: 9 models x {} configs ==", ctx.grid.len());
    let r = bench("fig4/nine_models_961cfg", &BenchOpts::default(), || {
        fig4_heatmaps(&ctx)
    });
    println!("   -> {:.0} (model,config) evaluations/s", throughput(&r, total));

    let data = fig4_heatmaps(&ctx);
    for d in &data {
        let (h, w, e) = d.energy.min_cell();
        println!("   {:<16} min E {e:.3e} at ({h:>3}, {w:>3})", d.network);
    }
}
