//! Bench FIG2: regenerate the ResNet-152 data-movement & utilization
//! heatmaps (961 configurations) and report sweep throughput.

use camuy::report::figures::{fig2_heatmaps, FigureContext};
use camuy::util::bench::{bench, throughput, BenchOpts};

fn main() {
    let ctx = FigureContext::paper();
    println!("== FIG2: ResNet-152 heatmaps over {} configs ==", ctx.grid.len());
    let r = bench("fig2/resnet152_961cfg", &BenchOpts::default(), || {
        fig2_heatmaps("resnet152", &ctx)
    });
    println!(
        "   -> {:.0} configs/s",
        throughput(&r, ctx.grid.len() as u64)
    );

    // Single-thread reference (the parallel-speedup datum for §Perf).
    let mut ctx1 = ctx.clone();
    ctx1.threads = 1;
    let r1 = bench("fig2/resnet152_961cfg_1thread", &BenchOpts::default(), || {
        fig2_heatmaps("resnet152", &ctx1)
    });
    println!(
        "   -> parallel speedup {:.2}x on {} threads",
        r1.seconds.mean / r.seconds.mean,
        ctx.threads
    );

    // The data itself, for the record.
    let data = fig2_heatmaps("resnet152", &ctx);
    let (h, w, e) = data.energy.min_cell();
    println!("   min E = {e:.4e} at ({h}, {w})");
}
