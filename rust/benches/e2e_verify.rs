//! Bench E2E: artifact compile + execute latency through the PJRT runtime
//! (the served-request hot path), plus the functional emulator's event
//! throughput on the same workload. Skips gracefully when artifacts are
//! absent.

use camuy::arch::{EmulationMode, Emulator};
use camuy::config::ArrayConfig;
use camuy::runtime::{default_artifact_dir, Manifest, PjrtRuntime};
use camuy::tensor::Matrix;
use camuy::util::bench::{bench, throughput, BenchOpts};
use camuy::util::prng::Rng;

fn main() {
    println!("== E2E: PJRT request path + functional emulator ==");
    let Ok(manifest) = Manifest::load(&default_artifact_dir()) else {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");

    // Compile latency (cold-start cost per artifact).
    let entry = manifest.find("gemm_quickstart").unwrap().clone();
    bench("e2e/compile_gemm_quickstart", &BenchOpts::default(), || {
        rt.load(&entry.name, &entry.file).unwrap()
    });

    // Request latency on the compiled executable.
    let exe = rt.load(&entry.name, &entry.file).unwrap();
    let mut rng = Rng::new(1);
    let a = Matrix::random_small_int(128, 128, &mut rng);
    let w = Matrix::random_small_int(128, 128, &mut rng);
    let r = bench(
        "e2e/request_gemm_128 (pjrt)",
        &BenchOpts {
            warmup_iters: 5,
            measure_iters: 50,
        },
        || exe.run_gemm(&a, &w).unwrap(),
    );
    println!("   -> {:.0} req/s", throughput(&r, 1));

    // Functional emulator on the same GEMM: MAC-event throughput.
    let emu = Emulator::new(ArrayConfig::new(32, 32)).unwrap();
    let r = bench("e2e/emulator_gemm_128 (wavefront)", &BenchOpts::default(), || {
        emu.run_gemm(&a, &w, EmulationMode::Wavefront)
    });
    let macs = 128u64 * 128 * 128;
    println!("   -> {:.2e} MAC-events/s", throughput(&r, macs));

    let r = bench(
        "e2e/emulator_gemm_128 (cycle-accurate)",
        &BenchOpts::slow(),
        || emu.run_gemm(&a, &w, EmulationMode::CycleAccurate),
    );
    println!("   -> {:.2e} MAC-events/s", throughput(&r, macs));
}
