//! Micro-benchmarks of the hot paths the §Perf pass optimizes:
//! closed-form analytic metrics vs the pass-iterating reference, workload
//! deduplication, network-level evaluation, NSGA-II machinery — and the
//! headline number: full-zoo sweep throughput, shape-major vs the naive
//! config-major baseline, emitted machine-readably to `BENCH_sweep.json`
//! (override the path with `CAMUY_BENCH_OUT`) so the perf trajectory is
//! tracked PR over PR.

use camuy::config::{ArrayConfig, EnergyWeights};
use camuy::model::gemm::{ws_metrics, ws_metrics_ref};
use camuy::model::schedule::GemmShape;
use camuy::nets;
use camuy::pareto::dominance::{fast_non_dominated_sort, pareto_front_indices};
use camuy::sweep::grid::DimGrid;
use camuy::sweep::runner::{
    default_threads, sweep_workload, sweep_workload_config_major, Workload,
};
use camuy::util::bench::{bench, throughput, BenchOpts};
use camuy::util::json::Json;
use camuy::util::prng::Rng;

fn main() {
    println!("== micro: analytic model ==");
    // A late-ResNet bottleneck GEMM on a mid grid point.
    let g = GemmShape::new(196, 1152, 256);
    let cfg = ArrayConfig::new(96, 48);
    let opts = BenchOpts {
        warmup_iters: 100,
        measure_iters: 1000,
    };
    let fast = bench("micro/ws_metrics_closed_form", &opts, || ws_metrics(g, &cfg));
    let slow = bench(
        "micro/ws_metrics_pass_iter_ref",
        &BenchOpts::default(),
        || ws_metrics_ref(g, &cfg),
    );
    println!(
        "   -> closed form is {:.0}x faster than pass iteration",
        slow.seconds.mean / fast.seconds.mean
    );

    println!("\n== micro: network evaluation ==");
    let net = nets::build("densenet201").unwrap();
    bench("micro/workload_dedup_densenet201", &BenchOpts::default(), || {
        Workload::of(&net)
    });
    let wl = Workload::of(&net);
    let r = bench("micro/densenet201_one_config", &opts, || wl.eval(&cfg));
    println!(
        "   -> {:.0} network-evals/s single thread",
        throughput(&r, 1)
    );
    // Without dedup (per-layer evaluation) for the §Perf comparison.
    let r2 = bench("micro/densenet201_one_config_nodedup", &BenchOpts::default(), || {
        net.layers
            .iter()
            .map(|l| l.metrics(&cfg))
            .fold(camuy::metrics::Metrics::default(), |a, b| a + b)
    });
    println!(
        "   -> dedup speedup {:.1}x",
        r2.seconds.mean / r.seconds.mean
    );

    println!("\n== sweep: full zoo, shape-major vs config-major ==");
    let sweep_json = bench_full_zoo_sweep();
    let out_path = std::env::var("CAMUY_BENCH_OUT").unwrap_or_else(|_| "BENCH_sweep.json".into());
    match std::fs::write(&out_path, sweep_json.to_string_pretty() + "\n") {
        Ok(()) => println!("   -> wrote {out_path}"),
        Err(e) => eprintln!("   -> could not write {out_path}: {e}"),
    }

    println!("\n== micro: pareto machinery ==");
    let mut rng = Rng::new(3);
    let points: Vec<Vec<f64>> = (0..961)
        .map(|_| vec![rng.next_f64(), rng.next_f64()])
        .collect();
    bench("micro/exhaustive_front_961", &BenchOpts::default(), || {
        pareto_front_indices(&points)
    });
    bench("micro/fast_nds_961", &BenchOpts::default(), || {
        fast_non_dominated_sort(&points)
    });

    println!("\n== micro: energy model ==");
    let m = ws_metrics(g, &cfg);
    let w = EnergyWeights::paper();
    bench("micro/eq1_energy", &opts, || m.energy(&w));
}

/// The full paper zoo over the paper's 961-point grid, both sweep cores,
/// same thread pool — the acceptance number for the shape-major refactor.
fn bench_full_zoo_sweep() -> Json {
    let grid = DimGrid::paper();
    let configs = grid.configs(&ArrayConfig::new(1, 1));
    let models = nets::paper_models();
    let workloads: Vec<Workload> = models.iter().map(Workload::of).collect();
    let threads = default_threads();
    let weights = EnergyWeights::paper();
    let total_configs = (configs.len() * workloads.len()) as u64;
    let opts = BenchOpts {
        warmup_iters: 1,
        measure_iters: 5,
    };

    // Sum energies so the whole evaluation is observably consumed.
    let naive = bench("sweep/full_zoo_config_major", &opts, || {
        workloads
            .iter()
            .flat_map(|wl| sweep_workload_config_major(wl, &configs, &weights, threads))
            .map(|p| p.energy)
            .sum::<f64>()
    });
    let shape_major = bench("sweep/full_zoo_shape_major", &opts, || {
        workloads
            .iter()
            .flat_map(|wl| sweep_workload(wl, &configs, &weights, threads))
            .map(|p| p.energy)
            .sum::<f64>()
    });

    let naive_cps = throughput(&naive, total_configs);
    let fast_cps = throughput(&shape_major, total_configs);
    let speedup = naive.seconds.mean / shape_major.seconds.mean;
    println!(
        "   -> {:.0} configs/s config-major, {:.0} configs/s shape-major ({speedup:.2}x)",
        naive_cps, fast_cps
    );

    let variant = |r: &camuy::util::bench::BenchResult, cps: f64| -> Json {
        Json::obj(vec![
            ("seconds_mean", Json::num(r.seconds.mean)),
            ("seconds_min", Json::num(r.seconds.min)),
            ("seconds_p95", Json::num(r.seconds.p95)),
            ("configs_per_sec", Json::num(cps)),
        ])
    };
    Json::obj(vec![
        ("bench", Json::str("full_zoo_sweep")),
        ("grid_points", Json::num(configs.len() as f64)),
        ("models", Json::num(workloads.len() as f64)),
        (
            "distinct_shapes_total",
            Json::num(workloads.iter().map(Workload::distinct).sum::<usize>() as f64),
        ),
        ("threads", Json::num(threads as f64)),
        ("network_evals_per_iter", Json::num(total_configs as f64)),
        ("config_major", variant(&naive, naive_cps)),
        ("shape_major", variant(&shape_major, fast_cps)),
        ("speedup_shape_major_over_config_major", Json::num(speedup)),
    ])
}
