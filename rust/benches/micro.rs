//! Micro-benchmarks of the hot paths the §Perf pass optimizes:
//! closed-form analytic metrics vs the pass-iterating reference, workload
//! deduplication, network-level evaluation, NSGA-II machinery — and the
//! headline number: full-zoo sweep throughput through all three sweep
//! cores (segmented vs shape-major vs config-major, DESIGN.md §10/§4) on
//! the paper grid *and* the dense step-1 grid, emitted machine-readably to
//! `BENCH_sweep.json` (override the path with `CAMUY_BENCH_OUT`) so the
//! perf trajectory is tracked PR over PR.
//!
//! `CAMUY_BENCH_SMOKE=1` runs a reduced CI mode: fewer iterations, and
//! the dense grid drops its oracle rungs (keeping the scalar-segmented
//! vs vectorized pair). The process **fails** (exit 1) if the segmented
//! core is slower than the shape-major core on the WS dataflow, slower
//! than the cell-by-cell fallback on the OS dataflow (DESIGN.md §11),
//! or if the vectorized blocked core (DESIGN.md §12) is slower than the
//! scalar segmented core on the dense grid for either dataflow — so a
//! regression on any sweep hot path cannot land silently.

use camuy::config::{ArrayConfig, Dataflow, EnergyWeights};
use camuy::model::gemm::{ws_metrics, ws_metrics_ref};
use camuy::model::schedule::GemmShape;
use camuy::nets;
use camuy::pareto::dominance::{fast_non_dominated_sort, pareto_front_indices};
use camuy::sweep::grid::DimGrid;
use camuy::sweep::plan::PlanCache;
use camuy::sweep::runner::{
    default_threads, sweep_workload_config_major, sweep_workload_planned,
    sweep_workload_segmented_scalar, sweep_workload_shape_major, Workload,
};
use camuy::util::bench::{bench, throughput, BenchOpts, BenchResult};
use camuy::util::json::Json;
use camuy::util::prng::Rng;

fn main() {
    let smoke = std::env::var("CAMUY_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    println!("== micro: analytic model ==");
    // A late-ResNet bottleneck GEMM on a mid grid point.
    let g = GemmShape::new(196, 1152, 256);
    let cfg = ArrayConfig::new(96, 48);
    let opts = BenchOpts {
        warmup_iters: 100,
        measure_iters: 1000,
    };
    let fast = bench("micro/ws_metrics_closed_form", &opts, || ws_metrics(g, &cfg));
    let slow = bench(
        "micro/ws_metrics_pass_iter_ref",
        &BenchOpts::default(),
        || ws_metrics_ref(g, &cfg),
    );
    println!(
        "   -> closed form is {:.0}x faster than pass iteration",
        slow.seconds.mean / fast.seconds.mean
    );

    println!("\n== micro: network evaluation ==");
    let net = nets::build("densenet201").unwrap();
    bench("micro/workload_dedup_densenet201", &BenchOpts::default(), || {
        Workload::of(&net)
    });
    let wl = Workload::of(&net);
    let r = bench("micro/densenet201_one_config", &opts, || wl.eval(&cfg));
    println!(
        "   -> {:.0} network-evals/s single thread",
        throughput(&r, 1)
    );
    // Without dedup (per-layer evaluation) for the §Perf comparison.
    let r2 = bench("micro/densenet201_one_config_nodedup", &BenchOpts::default(), || {
        net.layers
            .iter()
            .map(|l| l.metrics(&cfg))
            .fold(camuy::metrics::Metrics::default(), |a, b| a + b)
    });
    println!(
        "   -> dedup speedup {:.1}x",
        r2.seconds.mean / r.seconds.mean
    );

    println!("\n== sweep: full zoo, segmented vs shape-major vs config-major ==");
    let sweep_json = bench_zoo_sweeps(smoke);
    let out_path = std::env::var("CAMUY_BENCH_OUT").unwrap_or_else(|_| "BENCH_sweep.json".into());
    match std::fs::write(&out_path, sweep_json.to_string_pretty() + "\n") {
        Ok(()) => println!("   -> wrote {out_path}"),
        Err(e) => eprintln!("   -> could not write {out_path}: {e}"),
    }

    println!("\n== micro: pareto machinery ==");
    let mut rng = Rng::new(3);
    let points: Vec<Vec<f64>> = (0..961)
        .map(|_| vec![rng.next_f64(), rng.next_f64()])
        .collect();
    bench("micro/exhaustive_front_961", &BenchOpts::default(), || {
        pareto_front_indices(&points)
    });
    bench("micro/fast_nds_961", &BenchOpts::default(), || {
        fast_non_dominated_sort(&points)
    });

    println!("\n== micro: energy model ==");
    let m = ws_metrics(g, &cfg);
    let w = EnergyWeights::paper();
    bench("micro/eq1_energy", &opts, || m.energy(&w));

    // Smoke mode is the CI gate: the segmented core regressing below its
    // baseline on either dataflow fails the run.
    if smoke {
        let speedup = sweep_json
            .get("paper_grid")
            .and_then(|p| p.get("speedup_segmented_over_shape_major"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        if speedup < 1.0 {
            eprintln!(
                "FAIL: segmented sweep is {speedup:.2}x the shape-major core \
                 on the paper grid (must be >= 1.0)"
            );
            std::process::exit(1);
        }
        let os_speedup = sweep_json
            .get("paper_grid_os")
            .and_then(|p| p.get("speedup_os_segmented_over_fallback"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        if os_speedup < 1.0 {
            eprintln!(
                "FAIL: OS-segmented sweep is {os_speedup:.2}x the cell-by-cell \
                 fallback on the paper grid (must be >= 1.0)"
            );
            std::process::exit(1);
        }
        let vec_speedup = sweep_json
            .get("dense_grid")
            .and_then(|p| p.get("speedup_vectorized_over_segmented_scalar"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        if vec_speedup < 1.0 {
            eprintln!(
                "FAIL: the vectorized blocked WS core is {vec_speedup:.2}x the \
                 scalar segmented core on the dense grid (must be >= 1.0)"
            );
            std::process::exit(1);
        }
        let os_vec_speedup = sweep_json
            .get("dense_grid_os")
            .and_then(|p| p.get("speedup_os_vectorized_over_segmented_scalar"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        if os_vec_speedup < 1.0 {
            eprintln!(
                "FAIL: the vectorized blocked OS core is {os_vec_speedup:.2}x the \
                 scalar segmented core on the dense grid (must be >= 1.0)"
            );
            std::process::exit(1);
        }
        println!(
            "smoke gate passed: segmented is {speedup:.2}x shape-major (WS), \
             {os_speedup:.2}x fallback (OS); vectorized is {vec_speedup:.2}x \
             scalar segmented (WS dense), {os_vec_speedup:.2}x (OS dense)"
        );
    }
}

/// The per-rung JSON entry: timing summary, throughput, and the cell
/// count one iteration evaluates (`cells` — grid points × models), so
/// BENCH_sweep.json entries are comparable across machines and grids.
fn variant(r: &BenchResult, cells: u64) -> Json {
    Json::obj(vec![
        ("seconds_mean", Json::num(r.seconds.mean)),
        ("seconds_min", Json::num(r.seconds.min)),
        ("seconds_p95", Json::num(r.seconds.p95)),
        ("cells", Json::num(cells as f64)),
        ("configs_per_sec", Json::num(throughput(r, cells))),
    ])
}

/// One grid through the WS sweep cores over the whole paper zoo, same
/// thread pool: the vectorized blocked core (`segmented`) against the
/// scalar segmented rung (`segmented_scalar`), plus optionally the
/// shape-major core and the config-major oracle (both skipped on the
/// dense grid in CI smoke). Both segmented rungs share `plans`, which
/// is warmed with one untimed pass so the timed rungs measure the cell
/// loops, not segment-table construction.
fn bench_grid(
    label: &str,
    grid: &DimGrid,
    workloads: &[Workload],
    opts: &BenchOpts,
    plans: &PlanCache,
    include_config_major: bool,
    include_shape_major: bool,
) -> Json {
    let configs = grid.configs(&ArrayConfig::new(1, 1));
    let threads = default_threads();
    let weights = EnergyWeights::paper();
    let total_configs = (configs.len() * workloads.len()) as u64;

    // Warm the plan cache: segment tables are built (or re-fetched) here,
    // never inside a timed rung.
    for wl in workloads {
        sweep_workload_planned(wl, &configs, &weights, threads, Some(plans));
    }

    // Sum energies so the whole evaluation is observably consumed.
    let naive = if include_config_major {
        Some(bench(&format!("sweep/{label}_config_major"), opts, || {
            workloads
                .iter()
                .flat_map(|wl| sweep_workload_config_major(wl, &configs, &weights, threads))
                .map(|p| p.energy)
                .sum::<f64>()
        }))
    } else {
        None
    };
    let shape_major = if include_shape_major {
        Some(bench(&format!("sweep/{label}_shape_major"), opts, || {
            workloads
                .iter()
                .flat_map(|wl| sweep_workload_shape_major(wl, &configs, &weights, threads))
                .map(|p| p.energy)
                .sum::<f64>()
        }))
    } else {
        None
    };
    let scalar = bench(&format!("sweep/{label}_segmented_scalar"), opts, || {
        workloads
            .iter()
            .flat_map(|wl| {
                sweep_workload_segmented_scalar(wl, &configs, &weights, threads, Some(plans))
            })
            .map(|p| p.energy)
            .sum::<f64>()
    });
    let segmented = bench(&format!("sweep/{label}_segmented"), opts, || {
        workloads
            .iter()
            .flat_map(|wl| sweep_workload_planned(wl, &configs, &weights, threads, Some(plans)))
            .map(|p| p.energy)
            .sum::<f64>()
    });

    let vec_speedup = scalar.seconds.mean / segmented.seconds.mean;
    println!(
        "   -> {label}: {:.0} configs/s scalar segmented, {:.0} configs/s vectorized \
         ({vec_speedup:.2}x)",
        throughput(&scalar, total_configs),
        throughput(&segmented, total_configs),
    );

    let mut fields = vec![
        ("grid_points", Json::num(configs.len() as f64)),
        ("network_evals_per_iter", Json::num(total_configs as f64)),
        ("segmented_scalar", variant(&scalar, total_configs)),
        ("segmented", variant(&segmented, total_configs)),
        (
            "speedup_vectorized_over_segmented_scalar",
            Json::num(vec_speedup),
        ),
    ];
    if let Some(sm) = &shape_major {
        fields.push(("shape_major", variant(sm, total_configs)));
        fields.push((
            "speedup_segmented_over_shape_major",
            Json::num(sm.seconds.mean / segmented.seconds.mean),
        ));
    }
    if let Some(naive) = &naive {
        fields.push(("config_major", variant(naive, total_configs)));
        if let Some(sm) = &shape_major {
            fields.push((
                "speedup_shape_major_over_config_major",
                Json::num(naive.seconds.mean / sm.seconds.mean),
            ));
        }
        fields.push((
            "speedup_segmented_over_config_major",
            Json::num(naive.seconds.mean / segmented.seconds.mean),
        ));
    }
    Json::obj(fields)
}

/// One grid through the OS-dataflow sweep: the vectorized blocked OS
/// plan against the scalar segmented rung and (optionally — skipped on
/// the dense grid in CI smoke) the cell-by-cell `os_metrics` fallback
/// the config-major oracle still runs, which is exactly the path
/// *every* OS sweep took before the OS segment algebra landed.
fn bench_grid_os(
    label: &str,
    grid: &DimGrid,
    workloads: &[Workload],
    opts: &BenchOpts,
    plans: &PlanCache,
    include_fallback: bool,
) -> Json {
    let template = ArrayConfig::new(1, 1).with_dataflow(Dataflow::OutputStationary);
    let configs = grid.configs(&template);
    let threads = default_threads();
    let weights = EnergyWeights::paper();
    let total_configs = (configs.len() * workloads.len()) as u64;

    // Warm the plan cache before any timed rung.
    for wl in workloads {
        sweep_workload_planned(wl, &configs, &weights, threads, Some(plans));
    }

    let fallback = if include_fallback {
        Some(bench(&format!("sweep/{label}_os_fallback"), opts, || {
            workloads
                .iter()
                .flat_map(|wl| sweep_workload_config_major(wl, &configs, &weights, threads))
                .map(|p| p.energy)
                .sum::<f64>()
        }))
    } else {
        None
    };
    let scalar = bench(&format!("sweep/{label}_os_segmented_scalar"), opts, || {
        workloads
            .iter()
            .flat_map(|wl| {
                sweep_workload_segmented_scalar(wl, &configs, &weights, threads, Some(plans))
            })
            .map(|p| p.energy)
            .sum::<f64>()
    });
    let segmented = bench(&format!("sweep/{label}_os_segmented"), opts, || {
        workloads
            .iter()
            .flat_map(|wl| sweep_workload_planned(wl, &configs, &weights, threads, Some(plans)))
            .map(|p| p.energy)
            .sum::<f64>()
    });
    let vec_speedup = scalar.seconds.mean / segmented.seconds.mean;
    println!(
        "   -> {label} OS: {:.0} configs/s scalar segmented, {:.0} configs/s vectorized \
         ({vec_speedup:.2}x)",
        throughput(&scalar, total_configs),
        throughput(&segmented, total_configs),
    );
    let mut fields = vec![
        ("grid_points", Json::num(configs.len() as f64)),
        ("network_evals_per_iter", Json::num(total_configs as f64)),
        ("segmented_scalar", variant(&scalar, total_configs)),
        ("segmented", variant(&segmented, total_configs)),
        (
            "speedup_os_vectorized_over_segmented_scalar",
            Json::num(vec_speedup),
        ),
    ];
    if let Some(fb) = &fallback {
        fields.push(("fallback", variant(fb, total_configs)));
        fields.push((
            "speedup_os_segmented_over_fallback",
            Json::num(fb.seconds.mean / segmented.seconds.mean),
        ));
    }
    Json::obj(fields)
}

/// The full paper zoo through all the sweep cores — the acceptance
/// numbers for the segmented refactor and the vectorized blocked
/// kernels: the paper's 961-point grid on both dataflows, and the dense
/// step-1 grid where the axis collapse and the fused kernels shine.
fn bench_zoo_sweeps(smoke: bool) -> Json {
    let models = nets::paper_models();
    let workloads: Vec<Workload> = models.iter().map(Workload::of).collect();
    let plans = PlanCache::new();
    let opts = if smoke {
        BenchOpts {
            warmup_iters: 1,
            measure_iters: 2,
        }
    } else {
        BenchOpts {
            warmup_iters: 1,
            measure_iters: 5,
        }
    };

    let paper = bench_grid(
        "full_zoo_paper",
        &DimGrid::paper(),
        &workloads,
        &opts,
        &plans,
        !smoke,
        true,
    );
    let paper_os = bench_grid_os(
        "full_zoo_paper",
        &DimGrid::paper(),
        &workloads,
        &opts,
        &plans,
        true,
    );
    // The dense step-1 grid runs in smoke mode too (vectorized and
    // scalar segmented rungs only — no oracles): the CI gate requires
    // the fused kernels to beat the scalar core where it matters most.
    let dense_opts = BenchOpts {
        warmup_iters: 1,
        measure_iters: 2,
    };
    let dense = bench_grid(
        "full_zoo_dense",
        &DimGrid::dense(),
        &workloads,
        &dense_opts,
        &plans,
        !smoke,
        !smoke,
    );
    let dense_os = bench_grid_os(
        "full_zoo_dense",
        &DimGrid::dense(),
        &workloads,
        &dense_opts,
        &plans,
        !smoke,
    );
    let ps = plans.stats();
    Json::obj(vec![
        ("bench", Json::str("full_zoo_sweep")),
        ("smoke", Json::Bool(smoke)),
        ("models", Json::num(workloads.len() as f64)),
        (
            "distinct_shapes_total",
            Json::num(workloads.iter().map(Workload::distinct).sum::<usize>() as f64),
        ),
        ("threads", Json::num(default_threads() as f64)),
        ("paper_grid", paper),
        ("paper_grid_os", paper_os),
        ("dense_grid", dense),
        ("dense_grid_os", dense_os),
        (
            "plan_cache",
            Json::obj(vec![
                ("entries", Json::num(ps.entries as f64)),
                ("table_words", Json::num(ps.table_words as f64)),
                ("hits", Json::num(ps.hits as f64)),
                ("misses", Json::num(ps.misses as f64)),
                ("hit_rate", Json::num(ps.hit_rate())),
            ]),
        ),
    ])
}
