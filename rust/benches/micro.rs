//! Micro-benchmarks of the hot paths the §Perf pass optimizes:
//! closed-form analytic metrics vs the pass-iterating reference, workload
//! deduplication, network-level evaluation, and NSGA-II machinery.

use camuy::config::{ArrayConfig, EnergyWeights};
use camuy::model::gemm::{ws_metrics, ws_metrics_ref};
use camuy::model::schedule::GemmShape;
use camuy::nets;
use camuy::pareto::dominance::{fast_non_dominated_sort, pareto_front_indices};
use camuy::sweep::runner::Workload;
use camuy::util::bench::{bench, throughput, BenchOpts};
use camuy::util::prng::Rng;

fn main() {
    println!("== micro: analytic model ==");
    // A late-ResNet bottleneck GEMM on a mid grid point.
    let g = GemmShape::new(196, 1152, 256);
    let cfg = ArrayConfig::new(96, 48);
    let opts = BenchOpts {
        warmup_iters: 100,
        measure_iters: 1000,
    };
    let fast = bench("micro/ws_metrics_closed_form", &opts, || ws_metrics(g, &cfg));
    let slow = bench(
        "micro/ws_metrics_pass_iter_ref",
        &BenchOpts::default(),
        || ws_metrics_ref(g, &cfg),
    );
    println!(
        "   -> closed form is {:.0}x faster than pass iteration",
        slow.seconds.mean / fast.seconds.mean
    );

    println!("\n== micro: network evaluation ==");
    let net = nets::build("densenet201").unwrap();
    bench("micro/workload_dedup_densenet201", &BenchOpts::default(), || {
        Workload::of(&net)
    });
    let wl = Workload::of(&net);
    let r = bench("micro/densenet201_one_config", &opts, || wl.eval(&cfg));
    println!(
        "   -> {:.0} network-evals/s single thread",
        throughput(&r, 1)
    );
    // Without dedup (per-layer evaluation) for the §Perf comparison.
    let r2 = bench("micro/densenet201_one_config_nodedup", &BenchOpts::default(), || {
        net.metrics(&cfg)
    });
    println!(
        "   -> dedup speedup {:.1}x",
        r2.seconds.mean / r.seconds.mean
    );

    println!("\n== micro: pareto machinery ==");
    let mut rng = Rng::new(3);
    let points: Vec<Vec<f64>> = (0..961)
        .map(|_| vec![rng.next_f64(), rng.next_f64()])
        .collect();
    bench("micro/exhaustive_front_961", &BenchOpts::default(), || {
        pareto_front_indices(&points)
    });
    bench("micro/fast_nds_961", &BenchOpts::default(), || {
        fast_non_dominated_sort(&points)
    });

    println!("\n== micro: energy model ==");
    let m = ws_metrics(g, &cfg);
    let w = EnergyWeights::paper();
    bench("micro/eq1_energy", &opts, || m.energy(&w));
}
