//! Serving-path benchmarks: the request throughput `camuy serve` sees
//! through the `api::Engine` — cold engine vs memo-hot engine vs the
//! batched shape-major dispatch path — emitted machine-readably to
//! `BENCH_api.json` (override with `CAMUY_BENCH_API_OUT`) so the serving
//! trajectory is tracked PR over PR alongside `BENCH_sweep.json`.

use camuy::api::{Engine, EvalRequest};
use camuy::config::ArrayConfig;
use camuy::sweep::runner::default_threads;
use camuy::util::bench::{bench, throughput, BenchOpts, BenchResult};
use camuy::util::json::Json;

/// A serving-shaped request mix: one hot model queried across a spread of
/// geometries (what a design-space-exploration client sends).
fn requests() -> Vec<EvalRequest> {
    let mut out = Vec::new();
    for h in (16..=64).step_by(8) {
        for w in (16..=64).step_by(8) {
            out.push(EvalRequest::new("resnet152", ArrayConfig::new(h, w)));
        }
    }
    out
}

fn main() {
    let reqs = requests();
    let n = reqs.len() as u64;
    let opts = BenchOpts {
        warmup_iters: 1,
        measure_iters: 5,
    };

    println!("== api: engine eval throughput ({n} requests/iter) ==");
    let cold = bench("api/eval_sequential_cold", &opts, || {
        let engine = Engine::new();
        reqs.iter()
            .map(|r| engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    let batched = bench("api/eval_batched_cold", &opts, || {
        let engine = Engine::new();
        engine
            .eval_batch(&reqs, default_threads())
            .into_iter()
            .map(|r| r.unwrap().total().cycles)
            .sum::<u64>()
    });
    let warm_engine = Engine::new();
    let _ = warm_engine.eval_batch(&reqs, default_threads());
    let hot = bench("api/eval_memo_hot", &opts, || {
        reqs.iter()
            .map(|r| warm_engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    println!(
        "   -> {:.0} req/s sequential-cold, {:.0} req/s batched-cold, {:.0} req/s memo-hot",
        throughput(&cold, n),
        throughput(&batched, n),
        throughput(&hot, n),
    );
    println!(
        "   -> cache after warmup: {} entries, {} hits / {} misses",
        warm_engine.cache().len(),
        warm_engine.cache().hits(),
        warm_engine.cache().misses(),
    );

    let variant = |r: &BenchResult| -> Json {
        Json::obj(vec![
            ("seconds_mean", Json::num(r.seconds.mean)),
            ("seconds_min", Json::num(r.seconds.min)),
            ("seconds_p95", Json::num(r.seconds.p95)),
            ("requests_per_sec", Json::num(throughput(r, n))),
        ])
    };
    let doc = Json::obj(vec![
        ("bench", Json::str("api_engine_eval")),
        ("requests_per_iter", Json::num(n as f64)),
        ("network", Json::str("resnet152")),
        ("sequential_cold", variant(&cold)),
        ("batched_cold", variant(&batched)),
        ("memo_hot", variant(&hot)),
        (
            "speedup_hot_over_cold",
            Json::num(cold.seconds.mean / hot.seconds.mean),
        ),
    ]);
    let out =
        std::env::var("CAMUY_BENCH_API_OUT").unwrap_or_else(|_| "BENCH_api.json".to_string());
    match std::fs::write(&out, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("   -> wrote {out}"),
        Err(e) => eprintln!("   -> could not write {out}: {e}"),
    }
}
