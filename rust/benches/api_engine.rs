//! Serving-path benchmarks: the request throughput `camuy serve` sees
//! through the `api::Engine` — cold engine vs memo-hot engine vs the
//! batched segmented dispatch path, repeated sweep requests with and
//! without the engine-level plan cache (DESIGN.md §10), and the serve
//! batch fan-out through the persistent work-stealing pool vs the
//! pre-§11 per-call scoped-spawn pool — emitted machine-readably to
//! `BENCH_api.json` (override with `CAMUY_BENCH_API_OUT`) so the serving
//! trajectory is tracked PR over PR alongside `BENCH_sweep.json`.
//!
//! `CAMUY_BENCH_SMOKE=1` is the CI gate: the process fails (exit 1) if
//! batched fan-out throughput on the persistent pool drops below the
//! per-call-spawn baseline, if the telemetry-enabled memo-hot path
//! costs more than 3% over the disabled one (DESIGN.md §14), or if the
//! per-request deadline guard costs more than 3% over the bare loop
//! (DESIGN.md §15).

use camuy::api::{Engine, EvalRequest, SweepRequest, SweepSpec};
use camuy::config::ArrayConfig;
use camuy::runtime::pool;
use camuy::sweep::runner::default_threads;
use camuy::util::bench::{bench, throughput, BenchOpts, BenchResult};
use camuy::util::json::Json;

/// The pre-§11 fan-out baseline, preserved here (not in the library — it
/// is strictly worse than the pool and must not be reachable by library
/// users): scoped OS threads spawned per call, stealing indices from an
/// atomic cursor.
fn parallel_map_spawned<T: Send + Sync>(
    n: usize,
    threads: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::OnceLock;
    let workers = threads.max(1).min(n);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<OnceLock<T>> = (0..n).map(|_| OnceLock::new()).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let _ = slots[i].set(f(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().expect("all slots filled"))
        .collect()
}

/// A serving-shaped request mix: one hot model queried across a spread of
/// geometries (what a design-space-exploration client sends).
fn requests() -> Vec<EvalRequest> {
    let mut out = Vec::new();
    for h in (16..=64).step_by(8) {
        for w in (16..=64).step_by(8) {
            out.push(EvalRequest::new("resnet152", ArrayConfig::new(h, w)));
        }
    }
    out
}

fn main() {
    let reqs = requests();
    let n = reqs.len() as u64;
    let opts = BenchOpts {
        warmup_iters: 1,
        measure_iters: 5,
    };

    println!("== api: engine eval throughput ({n} requests/iter) ==");
    let cold = bench("api/eval_sequential_cold", &opts, || {
        let engine = Engine::new();
        reqs.iter()
            .map(|r| engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    let batched = bench("api/eval_batched_cold", &opts, || {
        let engine = Engine::new();
        engine
            .eval_batch(&reqs, default_threads())
            .into_iter()
            .map(|r| r.unwrap().total().cycles)
            .sum::<u64>()
    });
    let warm_engine = Engine::new();
    let _ = warm_engine.eval_batch(&reqs, default_threads());
    let hot = bench("api/eval_memo_hot", &opts, || {
        reqs.iter()
            .map(|r| warm_engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    println!(
        "   -> {:.0} req/s sequential-cold, {:.0} req/s batched-cold, {:.0} req/s memo-hot",
        throughput(&cold, n),
        throughput(&batched, n),
        throughput(&hot, n),
    );
    println!(
        "   -> cache after warmup: {} entries, {} hits / {} misses",
        warm_engine.cache().len(),
        warm_engine.cache().hits(),
        warm_engine.cache().misses(),
    );

    // --- serve batch fan-out: the persistent pool vs per-call spawned
    // scoped threads (the pre-§11 dispatch). Memo-hot evals isolate the
    // dispatch overhead itself — exactly what a serve batch of cached
    // requests pays per batch.
    println!("\n== api: batch fan-out, persistent pool vs per-call spawn ==");
    let fan_opts = BenchOpts {
        warmup_iters: 3,
        measure_iters: 30,
    };
    let fan_pool = bench("api/fanout_pool_persistent", &fan_opts, || {
        pool::parallel_map(reqs.len(), default_threads(), |i| {
            warm_engine.eval(&reqs[i]).unwrap().total().cycles
        })
        .iter()
        .sum::<u64>()
    });
    let fan_spawn = bench("api/fanout_spawn_per_call", &fan_opts, || {
        parallel_map_spawned(reqs.len(), default_threads(), |i| {
            warm_engine.eval(&reqs[i]).unwrap().total().cycles
        })
        .iter()
        .sum::<u64>()
    });
    let fan_speedup = fan_spawn.seconds.mean / fan_pool.seconds.mean;
    println!(
        "   -> {:.0} req/s on the persistent pool, {:.0} req/s spawning per call ({fan_speedup:.2}x)",
        throughput(&fan_pool, n),
        throughput(&fan_spawn, n),
    );

    // --- telemetry overhead: the same memo-hot eval loop with the
    // registry recording vs disabled. Request timers, striped counter
    // adds and histogram records are all relaxed atomics, so the
    // enabled path must stay within 3% of the disabled one — the smoke
    // gate below holds it there (DESIGN.md §14).
    println!("\n== api: telemetry overhead on the memo-hot path ==");
    camuy::telemetry::set_enabled(true);
    let tel_on = bench("api/eval_memo_hot_telemetry_on", &fan_opts, || {
        reqs.iter()
            .map(|r| warm_engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    camuy::telemetry::set_enabled(false);
    let tel_off = bench("api/eval_memo_hot_telemetry_off", &fan_opts, || {
        reqs.iter()
            .map(|r| warm_engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    camuy::telemetry::set_enabled(true);
    let tel_overhead = tel_on.seconds.min / tel_off.seconds.min;
    println!(
        "   -> {:.0} req/s recording, {:.0} req/s disabled ({:+.1}% best-over-best)",
        throughput(&tel_on, n),
        throughput(&tel_off, n),
        100.0 * (tel_overhead - 1.0),
    );

    // --- deadline-check overhead: the memo-hot eval loop with the full
    // per-request guard the serve tier applies to deadline-carrying
    // requests — a fresh token, the ambient install, checkpoint polls at
    // every chunk boundary, and the `catch_unwind` isolation — vs the
    // bare loop. The deadline is far in the future so no request ever
    // cancels; what is measured is purely the cost of being cancellable
    // (DESIGN.md §15). Must stay within 3% best-over-best.
    println!("\n== api: deadline-guard overhead on the memo-hot path ==");
    let deadline_on = bench("api/eval_memo_hot_deadline_on", &fan_opts, || {
        reqs.iter()
            .map(|r| {
                let token = camuy::robust::CancelToken::with_deadline_ms(60_000);
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    camuy::robust::with_token(&token, || {
                        warm_engine.eval(r).unwrap().total().cycles
                    })
                }));
                run.expect("a 60 s deadline never fires on a memo-hot eval")
            })
            .sum::<u64>()
    });
    let deadline_off = bench("api/eval_memo_hot_deadline_off", &fan_opts, || {
        reqs.iter()
            .map(|r| warm_engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    let deadline_overhead = deadline_on.seconds.min / deadline_off.seconds.min;
    println!(
        "   -> {:.0} req/s guarded, {:.0} req/s bare ({:+.1}% best-over-best)",
        throughput(&deadline_on, n),
        throughput(&deadline_off, n),
        100.0 * (deadline_overhead - 1.0),
    );

    // --- serve-mode repeated sweeps: segment-table reuse via the
    // engine-level plan cache (DESIGN.md §10). The same engine answers the
    // same sweep request over and over; the baseline clears the plan cache
    // before every request, isolating exactly the table-rebuild cost the
    // cache removes.
    println!("\n== api: repeated sweeps through the plan cache ==");
    let sweep_req = SweepRequest {
        net: "resnet152".to_string(),
        spec: SweepSpec::paper(),
    };
    let sweep_engine = Engine::new();
    let _ = sweep_engine.sweep(&sweep_req).unwrap(); // warm zoo + plan
    let sweep_nocache = bench("api/sweep_repeat_plan_cold", &opts, || {
        sweep_engine.plans().clear();
        sweep_engine.sweep(&sweep_req).unwrap().sweep.points.len()
    });
    let sweep_cached = bench("api/sweep_repeat_plan_hot", &opts, || {
        sweep_engine.sweep(&sweep_req).unwrap().sweep.points.len()
    });
    let plan_speedup = sweep_nocache.seconds.mean / sweep_cached.seconds.mean;
    println!(
        "   -> {:.0} sweeps/s rebuilding plans, {:.0} sweeps/s on plan-cache hits ({plan_speedup:.2}x); \
         {} plan(s) cached, {} hits / {} misses",
        throughput(&sweep_nocache, 1),
        throughput(&sweep_cached, 1),
        sweep_engine.plans().len(),
        sweep_engine.plans().hits(),
        sweep_engine.plans().misses(),
    );

    let variant = |r: &BenchResult| -> Json {
        Json::obj(vec![
            ("seconds_mean", Json::num(r.seconds.mean)),
            ("seconds_min", Json::num(r.seconds.min)),
            ("seconds_p95", Json::num(r.seconds.p95)),
            ("requests_per_sec", Json::num(throughput(r, n))),
        ])
    };
    let sweep_variant = |r: &BenchResult| -> Json {
        Json::obj(vec![
            ("seconds_mean", Json::num(r.seconds.mean)),
            ("seconds_min", Json::num(r.seconds.min)),
            ("seconds_p95", Json::num(r.seconds.p95)),
            ("sweeps_per_sec", Json::num(throughput(r, 1))),
        ])
    };
    let doc = Json::obj(vec![
        ("bench", Json::str("api_engine_eval")),
        ("requests_per_iter", Json::num(n as f64)),
        ("network", Json::str("resnet152")),
        ("sequential_cold", variant(&cold)),
        ("batched_cold", variant(&batched)),
        ("memo_hot", variant(&hot)),
        (
            "speedup_hot_over_cold",
            Json::num(cold.seconds.mean / hot.seconds.mean),
        ),
        ("fanout_pool_persistent", variant(&fan_pool)),
        ("fanout_spawn_per_call", variant(&fan_spawn)),
        ("speedup_pool_over_spawn", Json::num(fan_speedup)),
        ("telemetry_on", variant(&tel_on)),
        ("telemetry_off", variant(&tel_off)),
        ("overhead_telemetry_on_over_off", Json::num(tel_overhead)),
        ("deadline_on", variant(&deadline_on)),
        ("deadline_off", variant(&deadline_off)),
        ("overhead_deadline_on_over_off", Json::num(deadline_overhead)),
        ("sweep_repeat_plan_cold", sweep_variant(&sweep_nocache)),
        ("sweep_repeat_plan_hot", sweep_variant(&sweep_cached)),
        (
            "speedup_plan_hot_over_cold",
            Json::num(plan_speedup),
        ),
        (
            "plan_cache",
            Json::obj(vec![
                ("plans", Json::num(sweep_engine.plans().len() as f64)),
                ("hits", Json::num(sweep_engine.plans().hits() as f64)),
                ("misses", Json::num(sweep_engine.plans().misses() as f64)),
            ]),
        ),
    ]);
    let out =
        std::env::var("CAMUY_BENCH_API_OUT").unwrap_or_else(|_| "BENCH_api.json".to_string());
    match std::fs::write(&out, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("   -> wrote {out}"),
        Err(e) => eprintln!("   -> could not write {out}: {e}"),
    }

    // Smoke mode is the CI gate: batched serve fan-out must not fall
    // below the per-call-spawn baseline it replaced. Gated on the
    // best-over-best ratio rather than the means — each rung's `min` is
    // its structural cost with scheduler noise stripped, so a loaded CI
    // runner cannot flake a regression-free commit red.
    let smoke = std::env::var("CAMUY_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    if smoke {
        let best_ratio = fan_spawn.seconds.min / fan_pool.seconds.min;
        if best_ratio < 1.0 {
            eprintln!(
                "FAIL: persistent-pool fan-out is {best_ratio:.2}x the per-call-spawn \
                 baseline best-over-best (must be >= 1.0; means: {fan_speedup:.2}x)"
            );
            std::process::exit(1);
        }
        println!(
            "smoke gate passed: pool fan-out is {best_ratio:.2}x per-call spawn \
             (best-over-best; means {fan_speedup:.2}x)"
        );
        if tel_overhead > 1.03 {
            eprintln!(
                "FAIL: telemetry-enabled memo-hot evals cost {tel_overhead:.3}x the \
                 disabled path best-over-best (budget 1.03x)"
            );
            std::process::exit(1);
        }
        println!(
            "smoke gate passed: telemetry overhead {tel_overhead:.3}x (budget 1.03x)"
        );
        if deadline_overhead > 1.03 {
            eprintln!(
                "FAIL: deadline-guarded memo-hot evals cost {deadline_overhead:.3}x the \
                 bare path best-over-best (budget 1.03x)"
            );
            std::process::exit(1);
        }
        println!(
            "smoke gate passed: deadline-guard overhead {deadline_overhead:.3}x (budget 1.03x)"
        );
    }
}
