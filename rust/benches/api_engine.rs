//! Serving-path benchmarks: the request throughput `camuy serve` sees
//! through the `api::Engine` — cold engine vs memo-hot engine vs the
//! batched segmented dispatch path, plus repeated sweep requests with and
//! without the engine-level plan cache (DESIGN.md §10) — emitted
//! machine-readably to `BENCH_api.json` (override with
//! `CAMUY_BENCH_API_OUT`) so the serving trajectory is tracked PR over PR
//! alongside `BENCH_sweep.json`.

use camuy::api::{Engine, EvalRequest, SweepRequest, SweepSpec};
use camuy::config::ArrayConfig;
use camuy::sweep::runner::default_threads;
use camuy::util::bench::{bench, throughput, BenchOpts, BenchResult};
use camuy::util::json::Json;

/// A serving-shaped request mix: one hot model queried across a spread of
/// geometries (what a design-space-exploration client sends).
fn requests() -> Vec<EvalRequest> {
    let mut out = Vec::new();
    for h in (16..=64).step_by(8) {
        for w in (16..=64).step_by(8) {
            out.push(EvalRequest::new("resnet152", ArrayConfig::new(h, w)));
        }
    }
    out
}

fn main() {
    let reqs = requests();
    let n = reqs.len() as u64;
    let opts = BenchOpts {
        warmup_iters: 1,
        measure_iters: 5,
    };

    println!("== api: engine eval throughput ({n} requests/iter) ==");
    let cold = bench("api/eval_sequential_cold", &opts, || {
        let engine = Engine::new();
        reqs.iter()
            .map(|r| engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    let batched = bench("api/eval_batched_cold", &opts, || {
        let engine = Engine::new();
        engine
            .eval_batch(&reqs, default_threads())
            .into_iter()
            .map(|r| r.unwrap().total().cycles)
            .sum::<u64>()
    });
    let warm_engine = Engine::new();
    let _ = warm_engine.eval_batch(&reqs, default_threads());
    let hot = bench("api/eval_memo_hot", &opts, || {
        reqs.iter()
            .map(|r| warm_engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    println!(
        "   -> {:.0} req/s sequential-cold, {:.0} req/s batched-cold, {:.0} req/s memo-hot",
        throughput(&cold, n),
        throughput(&batched, n),
        throughput(&hot, n),
    );
    println!(
        "   -> cache after warmup: {} entries, {} hits / {} misses",
        warm_engine.cache().len(),
        warm_engine.cache().hits(),
        warm_engine.cache().misses(),
    );

    // --- serve-mode repeated sweeps: segment-table reuse via the
    // engine-level plan cache (DESIGN.md §10). The same engine answers the
    // same sweep request over and over; the baseline clears the plan cache
    // before every request, isolating exactly the table-rebuild cost the
    // cache removes.
    println!("\n== api: repeated sweeps through the plan cache ==");
    let sweep_req = SweepRequest {
        net: "resnet152".to_string(),
        spec: SweepSpec::paper(),
    };
    let sweep_engine = Engine::new();
    let _ = sweep_engine.sweep(&sweep_req).unwrap(); // warm zoo + plan
    let sweep_nocache = bench("api/sweep_repeat_plan_cold", &opts, || {
        sweep_engine.plans().clear();
        sweep_engine.sweep(&sweep_req).unwrap().sweep.points.len()
    });
    let sweep_cached = bench("api/sweep_repeat_plan_hot", &opts, || {
        sweep_engine.sweep(&sweep_req).unwrap().sweep.points.len()
    });
    let plan_speedup = sweep_nocache.seconds.mean / sweep_cached.seconds.mean;
    println!(
        "   -> {:.0} sweeps/s rebuilding plans, {:.0} sweeps/s on plan-cache hits ({plan_speedup:.2}x); \
         {} plan(s) cached, {} hits / {} misses",
        throughput(&sweep_nocache, 1),
        throughput(&sweep_cached, 1),
        sweep_engine.plans().len(),
        sweep_engine.plans().hits(),
        sweep_engine.plans().misses(),
    );

    let variant = |r: &BenchResult| -> Json {
        Json::obj(vec![
            ("seconds_mean", Json::num(r.seconds.mean)),
            ("seconds_min", Json::num(r.seconds.min)),
            ("seconds_p95", Json::num(r.seconds.p95)),
            ("requests_per_sec", Json::num(throughput(r, n))),
        ])
    };
    let sweep_variant = |r: &BenchResult| -> Json {
        Json::obj(vec![
            ("seconds_mean", Json::num(r.seconds.mean)),
            ("seconds_min", Json::num(r.seconds.min)),
            ("seconds_p95", Json::num(r.seconds.p95)),
            ("sweeps_per_sec", Json::num(throughput(r, 1))),
        ])
    };
    let doc = Json::obj(vec![
        ("bench", Json::str("api_engine_eval")),
        ("requests_per_iter", Json::num(n as f64)),
        ("network", Json::str("resnet152")),
        ("sequential_cold", variant(&cold)),
        ("batched_cold", variant(&batched)),
        ("memo_hot", variant(&hot)),
        (
            "speedup_hot_over_cold",
            Json::num(cold.seconds.mean / hot.seconds.mean),
        ),
        ("sweep_repeat_plan_cold", sweep_variant(&sweep_nocache)),
        ("sweep_repeat_plan_hot", sweep_variant(&sweep_cached)),
        (
            "speedup_plan_hot_over_cold",
            Json::num(plan_speedup),
        ),
        (
            "plan_cache",
            Json::obj(vec![
                ("plans", Json::num(sweep_engine.plans().len() as f64)),
                ("hits", Json::num(sweep_engine.plans().hits() as f64)),
                ("misses", Json::num(sweep_engine.plans().misses() as f64)),
            ]),
        ),
    ]);
    let out =
        std::env::var("CAMUY_BENCH_API_OUT").unwrap_or_else(|_| "BENCH_api.json".to_string());
    match std::fs::write(&out, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("   -> wrote {out}"),
        Err(e) => eprintln!("   -> could not write {out}: {e}"),
    }
}
