//! Serving-path benchmarks: the request throughput `camuy serve` sees
//! through the `api::Engine` — cold engine vs memo-hot engine vs the
//! batched segmented dispatch path, repeated sweep requests with and
//! without the engine-level plan cache (DESIGN.md §10), and the serve
//! batch fan-out through the persistent work-stealing pool vs the
//! pre-§11 per-call scoped-spawn pool — emitted machine-readably to
//! `BENCH_api.json` (override with `CAMUY_BENCH_API_OUT`) so the serving
//! trajectory is tracked PR over PR alongside `BENCH_sweep.json`.
//!
//! On Linux the bench also stress-drives the TCP front ends: 512
//! simultaneous connections, closed-loop, against both the epoll event
//! loop and the `--threaded` thread-per-connection oracle (DESIGN.md
//! §16), recording req/s plus client-observed p50/p99 latency for each.
//!
//! `CAMUY_BENCH_SMOKE=1` is the CI gate: the process fails (exit 1) if
//! batched fan-out throughput on the persistent pool drops below the
//! per-call-spawn baseline, if the telemetry-enabled memo-hot path
//! costs more than 3% over the disabled one (DESIGN.md §14), if the
//! per-request deadline guard costs more than 3% over the bare loop
//! (DESIGN.md §15), or if the event loop falls behind the threaded
//! front end under the 512-connection stress (`eventloop_over_threaded`
//! must stay >= 1.0).

use camuy::api::{Engine, EvalRequest, SweepRequest, SweepSpec};
use camuy::config::ArrayConfig;
use camuy::runtime::pool;
use camuy::sweep::runner::default_threads;
use camuy::util::bench::{bench, throughput, BenchOpts, BenchResult};
use camuy::util::json::Json;

/// Raise the open-file soft limit to the hard limit so the 512-connection
/// stress rung (server + client + clones ≈ 1600 fds in one process) never
/// trips a 1024 default. Raw syscall shim — the offline image ships no
/// `libc` crate (DESIGN.md §6).
#[cfg(target_os = "linux")]
fn raise_nofile_limit() {
    #[repr(C)]
    struct Rlimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut lim = Rlimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut lim) == 0 && lim.cur < lim.max {
            lim.cur = lim.max;
            if setrlimit(RLIMIT_NOFILE, &lim) != 0 {
                eprintln!("warning: could not raise RLIMIT_NOFILE; stress rung may fail");
            }
        }
    }
}

/// Connections held open simultaneously by the stress rung.
#[cfg(target_os = "linux")]
const STRESS_CONNS: usize = 512;
/// Requests sent per connection (closed-loop: write, then read the line).
#[cfg(target_os = "linux")]
const STRESS_ROUNDS: usize = 4;

/// One full stress round against the chosen TCP front end: 16 client
/// threads open 32 connections each, rendezvous so all 512 are live at
/// once, then drive them closed-loop — mostly memo-hot evals, with every
/// 16th connection sending one smoke sweep so the dispatchers see mixed
/// work. Per-request client-side latencies (nanoseconds) are appended to
/// `samples`.
#[cfg(target_os = "linux")]
fn stress_round(threaded: bool, samples: &std::sync::Mutex<Vec<u64>>) -> usize {
    use camuy::api::ServeOptions;
    use std::io::{BufRead, BufReader, Write};

    const THREADS: usize = 16;
    const PER_THREAD: usize = STRESS_CONNS / THREADS;
    const EVAL: &str =
        "{\"type\":\"eval\",\"net\":\"alexnet\",\"config\":{\"height\":24,\"width\":16}}\n";
    const SWEEP: &str =
        "{\"type\":\"sweep\",\"net\":\"alexnet\",\"grid\":\"smoke\",\"threads\":1}\n";

    let engine = Engine::new();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        threaded,
        max_connections: Some(STRESS_CONNS),
        max_concurrent: 2 * STRESS_CONNS,
        admission_max: 8 * STRESS_CONNS,
        idle_secs: 30,
        ..ServeOptions::default()
    };
    let barrier = std::sync::Barrier::new(THREADS);
    let mut served = 0usize;
    std::thread::scope(|s| {
        let engine = &engine;
        let opts = &opts;
        let barrier = &barrier;
        s.spawn(move || camuy::api::serve_tcp(engine, listener, opts).unwrap());
        let workers: Vec<_> = (0..THREADS)
            .map(|t| {
                s.spawn(move || {
                    let mut conns = Vec::with_capacity(PER_THREAD);
                    for _ in 0..PER_THREAD {
                        let c = std::net::TcpStream::connect(addr).unwrap();
                        let r = BufReader::new(c.try_clone().unwrap());
                        conns.push((c, r));
                    }
                    barrier.wait(); // all 512 connections are now live
                    let mut local = Vec::with_capacity(PER_THREAD * STRESS_ROUNDS);
                    let mut line = String::new();
                    for round in 0..STRESS_ROUNDS {
                        for (i, (c, r)) in conns.iter_mut().enumerate() {
                            let req = if round == 1 && (t * PER_THREAD + i) % 16 == 0 {
                                SWEEP
                            } else {
                                EVAL
                            };
                            let t0 = std::time::Instant::now();
                            c.write_all(req.as_bytes()).unwrap();
                            line.clear();
                            let k = r.read_line(&mut line).unwrap();
                            assert!(k > 0, "server closed a healthy connection");
                            local.push(t0.elapsed().as_nanos() as u64);
                        }
                    }
                    local
                })
            })
            .collect();
        let mut merged = samples.lock().unwrap();
        for w in workers {
            let local = w.join().unwrap();
            served += local.len();
            merged.extend(local);
        }
    });
    served
}

/// Exact-rank quantile of a sorted nanosecond sample set, in milliseconds.
#[cfg(target_os = "linux")]
fn quantile_ms(sorted: &[u64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = (((sorted.len() - 1) as f64) * q).round() as usize;
    sorted[i] as f64 / 1e6
}

/// The pre-§11 fan-out baseline, preserved here (not in the library — it
/// is strictly worse than the pool and must not be reachable by library
/// users): scoped OS threads spawned per call, stealing indices from an
/// atomic cursor.
fn parallel_map_spawned<T: Send + Sync>(
    n: usize,
    threads: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::OnceLock;
    let workers = threads.max(1).min(n);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<OnceLock<T>> = (0..n).map(|_| OnceLock::new()).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let _ = slots[i].set(f(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().expect("all slots filled"))
        .collect()
}

/// A serving-shaped request mix: one hot model queried across a spread of
/// geometries (what a design-space-exploration client sends).
fn requests() -> Vec<EvalRequest> {
    let mut out = Vec::new();
    for h in (16..=64).step_by(8) {
        for w in (16..=64).step_by(8) {
            out.push(EvalRequest::new("resnet152", ArrayConfig::new(h, w)));
        }
    }
    out
}

fn main() {
    let reqs = requests();
    let n = reqs.len() as u64;
    let opts = BenchOpts {
        warmup_iters: 1,
        measure_iters: 5,
    };

    println!("== api: engine eval throughput ({n} requests/iter) ==");
    let cold = bench("api/eval_sequential_cold", &opts, || {
        let engine = Engine::new();
        reqs.iter()
            .map(|r| engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    let batched = bench("api/eval_batched_cold", &opts, || {
        let engine = Engine::new();
        engine
            .eval_batch(&reqs, default_threads())
            .into_iter()
            .map(|r| r.unwrap().total().cycles)
            .sum::<u64>()
    });
    let warm_engine = Engine::new();
    let _ = warm_engine.eval_batch(&reqs, default_threads());
    let hot = bench("api/eval_memo_hot", &opts, || {
        reqs.iter()
            .map(|r| warm_engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    println!(
        "   -> {:.0} req/s sequential-cold, {:.0} req/s batched-cold, {:.0} req/s memo-hot",
        throughput(&cold, n),
        throughput(&batched, n),
        throughput(&hot, n),
    );
    println!(
        "   -> cache after warmup: {} entries, {} hits / {} misses",
        warm_engine.cache().len(),
        warm_engine.cache().hits(),
        warm_engine.cache().misses(),
    );

    // --- serve batch fan-out: the persistent pool vs per-call spawned
    // scoped threads (the pre-§11 dispatch). Memo-hot evals isolate the
    // dispatch overhead itself — exactly what a serve batch of cached
    // requests pays per batch.
    println!("\n== api: batch fan-out, persistent pool vs per-call spawn ==");
    let fan_opts = BenchOpts {
        warmup_iters: 3,
        measure_iters: 30,
    };
    let fan_pool = bench("api/fanout_pool_persistent", &fan_opts, || {
        pool::parallel_map(reqs.len(), default_threads(), |i| {
            warm_engine.eval(&reqs[i]).unwrap().total().cycles
        })
        .iter()
        .sum::<u64>()
    });
    let fan_spawn = bench("api/fanout_spawn_per_call", &fan_opts, || {
        parallel_map_spawned(reqs.len(), default_threads(), |i| {
            warm_engine.eval(&reqs[i]).unwrap().total().cycles
        })
        .iter()
        .sum::<u64>()
    });
    let fan_speedup = fan_spawn.seconds.mean / fan_pool.seconds.mean;
    println!(
        "   -> {:.0} req/s on the persistent pool, {:.0} req/s spawning per call ({fan_speedup:.2}x)",
        throughput(&fan_pool, n),
        throughput(&fan_spawn, n),
    );

    // --- telemetry overhead: the same memo-hot eval loop with the
    // registry recording vs disabled. Request timers, striped counter
    // adds and histogram records are all relaxed atomics, so the
    // enabled path must stay within 3% of the disabled one — the smoke
    // gate below holds it there (DESIGN.md §14).
    println!("\n== api: telemetry overhead on the memo-hot path ==");
    camuy::telemetry::set_enabled(true);
    let tel_on = bench("api/eval_memo_hot_telemetry_on", &fan_opts, || {
        reqs.iter()
            .map(|r| warm_engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    camuy::telemetry::set_enabled(false);
    let tel_off = bench("api/eval_memo_hot_telemetry_off", &fan_opts, || {
        reqs.iter()
            .map(|r| warm_engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    camuy::telemetry::set_enabled(true);
    let tel_overhead = tel_on.seconds.min / tel_off.seconds.min;
    println!(
        "   -> {:.0} req/s recording, {:.0} req/s disabled ({:+.1}% best-over-best)",
        throughput(&tel_on, n),
        throughput(&tel_off, n),
        100.0 * (tel_overhead - 1.0),
    );

    // --- deadline-check overhead: the memo-hot eval loop with the full
    // per-request guard the serve tier applies to deadline-carrying
    // requests — a fresh token, the ambient install, checkpoint polls at
    // every chunk boundary, and the `catch_unwind` isolation — vs the
    // bare loop. The deadline is far in the future so no request ever
    // cancels; what is measured is purely the cost of being cancellable
    // (DESIGN.md §15). Must stay within 3% best-over-best.
    println!("\n== api: deadline-guard overhead on the memo-hot path ==");
    let deadline_on = bench("api/eval_memo_hot_deadline_on", &fan_opts, || {
        reqs.iter()
            .map(|r| {
                let token = camuy::robust::CancelToken::with_deadline_ms(60_000);
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    camuy::robust::with_token(&token, || {
                        warm_engine.eval(r).unwrap().total().cycles
                    })
                }));
                run.expect("a 60 s deadline never fires on a memo-hot eval")
            })
            .sum::<u64>()
    });
    let deadline_off = bench("api/eval_memo_hot_deadline_off", &fan_opts, || {
        reqs.iter()
            .map(|r| warm_engine.eval(r).unwrap().total().cycles)
            .sum::<u64>()
    });
    let deadline_overhead = deadline_on.seconds.min / deadline_off.seconds.min;
    println!(
        "   -> {:.0} req/s guarded, {:.0} req/s bare ({:+.1}% best-over-best)",
        throughput(&deadline_on, n),
        throughput(&deadline_off, n),
        100.0 * (deadline_overhead - 1.0),
    );

    // --- serve-mode repeated sweeps: segment-table reuse via the
    // engine-level plan cache (DESIGN.md §10). The same engine answers the
    // same sweep request over and over; the baseline clears the plan cache
    // before every request, isolating exactly the table-rebuild cost the
    // cache removes.
    println!("\n== api: repeated sweeps through the plan cache ==");
    let sweep_req = SweepRequest {
        net: "resnet152".to_string(),
        spec: SweepSpec::paper(),
    };
    let sweep_engine = Engine::new();
    let _ = sweep_engine.sweep(&sweep_req).unwrap(); // warm zoo + plan
    let sweep_nocache = bench("api/sweep_repeat_plan_cold", &opts, || {
        sweep_engine.plans().clear();
        sweep_engine.sweep(&sweep_req).unwrap().sweep.points.len()
    });
    let sweep_cached = bench("api/sweep_repeat_plan_hot", &opts, || {
        sweep_engine.sweep(&sweep_req).unwrap().sweep.points.len()
    });
    let plan_speedup = sweep_nocache.seconds.mean / sweep_cached.seconds.mean;
    println!(
        "   -> {:.0} sweeps/s rebuilding plans, {:.0} sweeps/s on plan-cache hits ({plan_speedup:.2}x); \
         {} plan(s) cached, {} hits / {} misses",
        throughput(&sweep_nocache, 1),
        throughput(&sweep_cached, 1),
        sweep_engine.plans().len(),
        sweep_engine.plans().hits(),
        sweep_engine.plans().misses(),
    );

    // --- front-end stress: 512 simultaneous TCP connections driven
    // closed-loop against the epoll event loop and against the
    // thread-per-connection oracle it replaced (DESIGN.md §16). Same
    // request mix, same client harness; what differs is only how the
    // server multiplexes sockets. Client-side per-request latencies give
    // p50/p99 alongside the wall-clock throughput.
    #[cfg(target_os = "linux")]
    let (stress_ev, stress_th, stress_ratio, stress_ev_lat, stress_th_lat) = {
        raise_nofile_limit();
        println!(
            "\n== api: {STRESS_CONNS}-connection TCP stress, event loop vs thread-per-connection =="
        );
        let stress_opts = BenchOpts {
            warmup_iters: 1,
            measure_iters: 3,
        };
        let stress_n = (STRESS_CONNS * STRESS_ROUNDS) as u64;
        let ev_samples = std::sync::Mutex::new(Vec::new());
        let ev = bench("api/stress_512_eventloop", &stress_opts, || {
            stress_round(false, &ev_samples)
        });
        let th_samples = std::sync::Mutex::new(Vec::new());
        let th = bench("api/stress_512_threaded", &stress_opts, || {
            stress_round(true, &th_samples)
        });
        let ratio = th.seconds.min / ev.seconds.min;
        let mut ev_lat = ev_samples.into_inner().unwrap();
        ev_lat.sort_unstable();
        let mut th_lat = th_samples.into_inner().unwrap();
        th_lat.sort_unstable();
        println!(
            "   -> event loop: {:.0} req/s, p50 {:.2} ms, p99 {:.2} ms across {STRESS_CONNS} live connections",
            throughput(&ev, stress_n),
            quantile_ms(&ev_lat, 0.50),
            quantile_ms(&ev_lat, 0.99),
        );
        println!(
            "   -> threaded:   {:.0} req/s, p50 {:.2} ms, p99 {:.2} ms ({ratio:.2}x best-over-best, event loop's favor)",
            throughput(&th, stress_n),
            quantile_ms(&th_lat, 0.50),
            quantile_ms(&th_lat, 0.99),
        );
        (ev, th, ratio, ev_lat, th_lat)
    };

    let variant = |r: &BenchResult| -> Json {
        Json::obj(vec![
            ("seconds_mean", Json::num(r.seconds.mean)),
            ("seconds_min", Json::num(r.seconds.min)),
            ("seconds_p95", Json::num(r.seconds.p95)),
            ("requests_per_sec", Json::num(throughput(r, n))),
        ])
    };
    let sweep_variant = |r: &BenchResult| -> Json {
        Json::obj(vec![
            ("seconds_mean", Json::num(r.seconds.mean)),
            ("seconds_min", Json::num(r.seconds.min)),
            ("seconds_p95", Json::num(r.seconds.p95)),
            ("sweeps_per_sec", Json::num(throughput(r, 1))),
        ])
    };
    let mut doc_pairs = vec![
        ("bench", Json::str("api_engine_eval")),
        ("requests_per_iter", Json::num(n as f64)),
        ("network", Json::str("resnet152")),
        ("sequential_cold", variant(&cold)),
        ("batched_cold", variant(&batched)),
        ("memo_hot", variant(&hot)),
        (
            "speedup_hot_over_cold",
            Json::num(cold.seconds.mean / hot.seconds.mean),
        ),
        ("fanout_pool_persistent", variant(&fan_pool)),
        ("fanout_spawn_per_call", variant(&fan_spawn)),
        ("speedup_pool_over_spawn", Json::num(fan_speedup)),
        ("telemetry_on", variant(&tel_on)),
        ("telemetry_off", variant(&tel_off)),
        ("overhead_telemetry_on_over_off", Json::num(tel_overhead)),
        ("deadline_on", variant(&deadline_on)),
        ("deadline_off", variant(&deadline_off)),
        ("overhead_deadline_on_over_off", Json::num(deadline_overhead)),
        ("sweep_repeat_plan_cold", sweep_variant(&sweep_nocache)),
        ("sweep_repeat_plan_hot", sweep_variant(&sweep_cached)),
        (
            "speedup_plan_hot_over_cold",
            Json::num(plan_speedup),
        ),
        (
            "plan_cache",
            Json::obj(vec![
                ("plans", Json::num(sweep_engine.plans().len() as f64)),
                ("hits", Json::num(sweep_engine.plans().hits() as f64)),
                ("misses", Json::num(sweep_engine.plans().misses() as f64)),
            ]),
        ),
    ];
    #[cfg(target_os = "linux")]
    {
        let stress_n = (STRESS_CONNS * STRESS_ROUNDS) as u64;
        let stress_variant = |r: &BenchResult, lat: &[u64]| -> Json {
            Json::obj(vec![
                ("seconds_mean", Json::num(r.seconds.mean)),
                ("seconds_min", Json::num(r.seconds.min)),
                ("seconds_p95", Json::num(r.seconds.p95)),
                ("requests_per_sec", Json::num(throughput(r, stress_n))),
                ("latency_p50_ms", Json::num(quantile_ms(lat, 0.50))),
                ("latency_p99_ms", Json::num(quantile_ms(lat, 0.99))),
            ])
        };
        doc_pairs.push(("stress_connections", Json::num(STRESS_CONNS as f64)));
        doc_pairs.push((
            "stress_512_eventloop",
            stress_variant(&stress_ev, &stress_ev_lat),
        ));
        doc_pairs.push((
            "stress_512_threaded",
            stress_variant(&stress_th, &stress_th_lat),
        ));
        doc_pairs.push(("eventloop_over_threaded", Json::num(stress_ratio)));
    }
    let doc = Json::obj(doc_pairs);
    let out =
        std::env::var("CAMUY_BENCH_API_OUT").unwrap_or_else(|_| "BENCH_api.json".to_string());
    match std::fs::write(&out, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("   -> wrote {out}"),
        Err(e) => eprintln!("   -> could not write {out}: {e}"),
    }

    // Smoke mode is the CI gate: batched serve fan-out must not fall
    // below the per-call-spawn baseline it replaced. Gated on the
    // best-over-best ratio rather than the means — each rung's `min` is
    // its structural cost with scheduler noise stripped, so a loaded CI
    // runner cannot flake a regression-free commit red.
    let smoke = std::env::var("CAMUY_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    if smoke {
        let best_ratio = fan_spawn.seconds.min / fan_pool.seconds.min;
        if best_ratio < 1.0 {
            eprintln!(
                "FAIL: persistent-pool fan-out is {best_ratio:.2}x the per-call-spawn \
                 baseline best-over-best (must be >= 1.0; means: {fan_speedup:.2}x)"
            );
            std::process::exit(1);
        }
        println!(
            "smoke gate passed: pool fan-out is {best_ratio:.2}x per-call spawn \
             (best-over-best; means {fan_speedup:.2}x)"
        );
        if tel_overhead > 1.03 {
            eprintln!(
                "FAIL: telemetry-enabled memo-hot evals cost {tel_overhead:.3}x the \
                 disabled path best-over-best (budget 1.03x)"
            );
            std::process::exit(1);
        }
        println!(
            "smoke gate passed: telemetry overhead {tel_overhead:.3}x (budget 1.03x)"
        );
        if deadline_overhead > 1.03 {
            eprintln!(
                "FAIL: deadline-guarded memo-hot evals cost {deadline_overhead:.3}x the \
                 bare path best-over-best (budget 1.03x)"
            );
            std::process::exit(1);
        }
        println!(
            "smoke gate passed: deadline-guard overhead {deadline_overhead:.3}x (budget 1.03x)"
        );
        #[cfg(target_os = "linux")]
        {
            if stress_ratio < 1.0 {
                eprintln!(
                    "FAIL: under {STRESS_CONNS} connections the event-loop front end ran at \
                     {stress_ratio:.2}x the threaded oracle best-over-best (must be >= 1.0x — \
                     at least as fast as the thread-per-connection path it replaced)"
                );
                std::process::exit(1);
            }
            println!(
                "smoke gate passed: event loop sustained {STRESS_CONNS} connections at \
                 {stress_ratio:.2}x the threaded front end (best-over-best, must be >= 1.0x)"
            );
        }
    }
}
