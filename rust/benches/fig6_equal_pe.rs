//! Bench FIG6: equal-PE-count aspect-ratio study for 4096 / 16384 / 65536
//! PEs across the nine models, plus the SCALE-SIM baseline comparison.

use camuy::baseline::scalesim_metrics;
use camuy::config::ArrayConfig;
use camuy::nets;
use camuy::report::figures::{fig6_equal_pe, FigureContext};
use camuy::sweep::grid::equal_pe_factorizations;
use camuy::util::bench::{bench, BenchOpts};

fn main() {
    let ctx = FigureContext::paper();
    println!("== FIG6: equal-PE aspect ratios ==");
    bench("fig6/three_budgets_nine_models", &BenchOpts::default(), || {
        [4096usize, 16384, 65536]
            .iter()
            .map(|&b| fig6_equal_pe(b, 8, &ctx))
            .collect::<Vec<_>>()
    });

    let d = fig6_equal_pe(16384, 8, &ctx);
    println!("   PE budget 16384, avg normalized E:");
    for (i, &(h, w)) in d.shapes.iter().enumerate() {
        println!("   {h:>5} x {w:<5} {:.4}", d.average[i]);
    }

    // Baseline comparison for the same space.
    bench("fig6/scalesim_baseline_resnet152", &BenchOpts::default(), || {
        let net = nets::build("resnet152").unwrap();
        equal_pe_factorizations(16384, 8)
            .into_iter()
            .map(|(h, w)| {
                let cfg = ArrayConfig::new(h, w);
                net.layers
                    .iter()
                    .map(|l| {
                        let (g, groups) = l.gemm();
                        scalesim_metrics(g, &cfg).cycles * groups as u64
                    })
                    .sum::<u64>()
            })
            .collect::<Vec<_>>()
    });
}
