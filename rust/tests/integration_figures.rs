//! Integration over the full figure pipeline on reduced grids: sweeps,
//! normalization, Pareto extraction, NSGA-II-vs-exhaustive, writers, and
//! the qualitative paper findings the reproduction stands on.

use camuy::config::{ArrayConfig, EnergyWeights};
use camuy::nets;
use camuy::pareto::dominance::pareto_front_indices;
use camuy::pareto::nsga2::Nsga2Params;
use camuy::report::figures::{
    fig2_heatmaps, fig3_pareto, fig5_robust, fig6_equal_pe, FigureContext,
};
use camuy::sweep::grid::DimGrid;
use camuy::sweep::runner::{sweep_network, Workload};

fn ctx() -> FigureContext {
    let mut c = FigureContext::paper();
    c.grid = DimGrid::coarse(16, 128, 16); // 8x8 = 64 configs
    c.threads = 2;
    c
}

#[test]
fn small_arrays_win_on_energy_for_every_paper_model() {
    // The paper's headline (Section 4.2): data movement cost is minimal
    // for small arrays across all nine models.
    let c = ctx();
    for name in nets::PAPER_MODELS {
        let d = fig2_heatmaps(name, &c);
        let (h, w, _) = d.energy.min_cell();
        assert!(
            h <= 32 && w <= 48,
            "{name}: min at ({h}, {w}) — not a small array"
        );
    }
}

#[test]
fn group_conv_models_prefer_the_smallest_arrays() {
    // Grouped models' optimum E is at least as small (in PE count) as
    // plain models' (Section 4.2).
    let c = ctx();
    let pe_of_min = |name: &str| {
        let d = fig2_heatmaps(name, &c);
        let (h, w, _) = d.energy.min_cell();
        h * w
    };
    let grouped = ["resnext152", "mobilenetv3l", "efficientnetb0"];
    let plain = ["alexnet", "vgg16", "resnet152"];
    let max_grouped = grouped.iter().map(|n| pe_of_min(n)).max().unwrap();
    let min_plain = plain.iter().map(|n| pe_of_min(n)).min().unwrap();
    assert!(
        max_grouped <= min_plain,
        "grouped optima ({max_grouped} PEs) should be <= plain optima ({min_plain} PEs)"
    );
}

#[test]
fn fig3_nsga2_matches_exhaustive_front_exactly_on_small_grid() {
    let c = ctx();
    let params = Nsga2Params {
        population: 60,
        generations: 60,
        ..Default::default()
    };
    let d = fig3_pareto("resnet152", &c, &params);
    let mut got: Vec<(usize, usize)> = d.energy_front.iter().map(|s| (s.height, s.width)).collect();
    let mut want: Vec<(usize, usize)> = d
        .exhaustive_energy_front
        .iter()
        .map(|s| (s.height, s.width))
        .collect();
    got.sort_unstable();
    got.dedup();
    want.sort_unstable();
    want.dedup();
    assert_eq!(got, want, "NSGA-II must recover the exact front on 64 points");
}

#[test]
fn fig5_front_is_truly_non_dominated_and_knee_is_tall() {
    let c = ctx();
    let d = fig5_robust(&c, &Nsga2Params::default());
    // Non-domination against the full objective cloud.
    let all: Vec<Vec<f64>> = (0..d.objectives.len())
        .map(|i| vec![d.objectives.avg_norm_energy[i], d.objectives.avg_norm_cycles[i]])
        .collect();
    let front_idx = pareto_front_indices(&all);
    let true_front: std::collections::HashSet<(usize, usize)> = front_idx
        .iter()
        .map(|&i| (d.objectives.heights[i], d.objectives.widths[i]))
        .collect();
    for s in &d.front {
        assert!(
            true_front.contains(&(s.height, s.width)),
            "({}, {}) is dominated",
            s.height,
            s.width
        );
    }
    // Robustness finding: most Pareto configurations are height >= width.
    let tall = d.front.iter().filter(|s| s.height >= s.width).count();
    assert!(
        tall * 2 >= d.front.len(),
        "tall-narrow should dominate the robust front ({tall}/{})",
        d.front.len()
    );
}

#[test]
fn fig6_extreme_ratios_lose() {
    // Section 5 / Samajdar et al.: extreme height:width ratios perform
    // poorly — the ends of the equal-PE curve must be worse than the best
    // interior point.
    let c = ctx();
    for budget in [4096usize, 16384] {
        let d = fig6_equal_pe(budget, 8, &c);
        let best = d
            .average
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        let first = *d.average.first().unwrap();
        let last = *d.average.last().unwrap();
        assert!(
            first > best && last > best,
            "budget {budget}: extremes ({first:.3}, {last:.3}) vs best {best:.3}"
        );
    }
}

#[test]
fn power_of_two_widths_have_better_utilization() {
    // The Fig. 2 observation: power-of-two dims divide the (power-of-two)
    // channel counts, avoiding ragged tiles.
    let net = nets::build("resnet152").unwrap();
    let wl = Workload::of(&net);
    let u = |h: usize, w: usize| {
        let cfg = ArrayConfig::new(h, w);
        wl.eval(&cfg).utilization(cfg.pe_count())
    };
    assert!(u(64, 64) > u(64, 72), "64 should beat 72 in width");
    assert!(u(64, 64) > u(72, 64), "64 should beat 72 in height");
}

#[test]
fn tpu_geometry_is_pareto_dominated_for_modern_nets() {
    // The paper's motivating claim: the commercial 256x256 square is far
    // from the efficient frontier for modern CNNs.
    let cfgs: Vec<ArrayConfig> = DimGrid::paper().configs(&ArrayConfig::new(1, 1));
    let net = nets::build("mobilenetv3l").unwrap();
    let sweep = sweep_network(&net, &cfgs, &EnergyWeights::paper(), 4);
    let tpu = sweep
        .points
        .iter()
        .find(|p| p.height == 256 && p.width == 256)
        .unwrap();
    let dominators = sweep
        .points
        .iter()
        .filter(|p| {
            p.energy <= tpu.energy
                && p.metrics.cycles <= tpu.metrics.cycles
                && (p.energy < tpu.energy || p.metrics.cycles < tpu.metrics.cycles)
        })
        .count();
    assert!(
        dominators > 0,
        "some configuration must dominate the 256x256 TPU point"
    );
}

#[test]
fn writers_roundtrip_csv() {
    // Figure CSVs parse back with the right arity.
    let c = ctx();
    let tmp = std::env::temp_dir().join("camuy_int_fig");
    let _ = std::fs::remove_dir_all(&tmp);
    let d = fig2_heatmaps("alexnet", &c);
    camuy::report::figures::write_fig2(&d, &tmp).unwrap();
    let text = std::fs::read_to_string(tmp.join("fig2_alexnet.energy.csv")).unwrap();
    let mut lines = text.lines();
    assert_eq!(lines.next().unwrap(), "height,width,value");
    let rows: Vec<&str> = lines.collect();
    assert_eq!(rows.len(), c.grid.len());
    for r in rows {
        assert_eq!(r.split(',').count(), 3);
    }
    let _ = std::fs::remove_dir_all(&tmp);
}
