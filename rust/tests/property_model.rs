//! Property tests (DESIGN.md §7): the analytic closed form, the
//! pass-iterating reference, and the functional emulator (both engines)
//! must agree *exactly* — counters, cycles, passes — across randomized
//! GEMM shapes, array geometries and accumulator capacities; the
//! emulator's numerics must equal plain matmul; the shape-major sweep core
//! must be byte-identical to naive config-major evaluation on random
//! networks and grids; and the metrics algebra must satisfy its monoid /
//! scaling laws.

use camuy::arch::{EmulationMode, Emulator};
use camuy::config::{ArrayConfig, Dataflow, EnergyWeights};
use camuy::metrics::{Metrics, MovementCounters};
use camuy::model::gemm::{os_metrics, ws_metrics, ws_metrics_ref};
use camuy::model::layer::{Layer, SpatialDims};
use camuy::model::network::Network;
use camuy::model::schedule::GemmShape;
use camuy::model::workload::Workload;
use camuy::sweep::runner::{sweep_workload, sweep_workload_config_major};
use camuy::tensor::Matrix;
use camuy::util::prng::Rng;
use camuy::util::propcheck::{check, shrink_usize, Shrink};

#[derive(Debug, Clone)]
struct Case {
    m: usize,
    k: usize,
    n: usize,
    h: usize,
    w: usize,
    acc: usize,
}

impl Shrink for Case {
    fn shrink_candidates(&self) -> Vec<Case> {
        let mut out = Vec::new();
        let fields: [(usize, usize, fn(&Case, usize) -> Case); 6] = [
            (self.m, 1, |c, v| Case { m: v, ..c.clone() }),
            (self.k, 1, |c, v| Case { k: v, ..c.clone() }),
            (self.n, 1, |c, v| Case { n: v, ..c.clone() }),
            (self.h, 1, |c, v| Case { h: v, ..c.clone() }),
            (self.w, 1, |c, v| Case { w: v, ..c.clone() }),
            (self.acc, 1, |c, v| Case { acc: v, ..c.clone() }),
        ];
        for (cur, lo, make) in fields {
            for v in shrink_usize(cur, lo) {
                out.push(make(self, v));
            }
        }
        out
    }
}

fn gen_case(rng: &mut camuy::util::prng::Rng) -> Case {
    Case {
        m: rng.range_usize(1, 40),
        k: rng.range_usize(1, 40),
        n: rng.range_usize(1, 40),
        h: rng.range_usize(1, 12),
        w: rng.range_usize(1, 12),
        acc: rng.range_usize(1, 64),
    }
}

fn cfg_of(c: &Case) -> ArrayConfig {
    ArrayConfig::new(c.h, c.w).with_acc_capacity(c.acc)
}

#[test]
fn closed_form_equals_pass_iteration() {
    check(600, 0xC0FFEE, gen_case, |c| {
        let g = GemmShape::new(c.m, c.k, c.n);
        let fast = ws_metrics(g, &cfg_of(c));
        let slow = ws_metrics_ref(g, &cfg_of(c));
        if fast == slow {
            Ok(())
        } else {
            Err(format!("closed {fast:?}\n!= ref {slow:?}"))
        }
    });
}

#[test]
fn emulator_equals_analytic_model() {
    let mut data_rng = camuy::util::prng::Rng::new(0xDA7A);
    check(120, 0xBEEF, gen_case, |c| {
        let g = GemmShape::new(c.m, c.k, c.n);
        let cfg = cfg_of(c);
        let analytic = ws_metrics(g, &cfg);
        let emu = Emulator::new(cfg.clone()).map_err(|e| e.to_string())?;
        let a = Matrix::random_small_int(c.m, c.k, &mut data_rng);
        let w = Matrix::random_small_int(c.k, c.n, &mut data_rng);
        let res = emu.run_gemm(&a, &w, EmulationMode::Wavefront);
        if res.metrics != analytic {
            return Err(format!("emulator {:?}\n!= analytic {analytic:?}", res.metrics));
        }
        if res.output != a.matmul(&w) {
            return Err("numerics mismatch".to_string());
        }
        Ok(())
    });
}

#[test]
fn cycle_accurate_engine_equals_wavefront() {
    let mut data_rng = camuy::util::prng::Rng::new(0x51DE);
    check(40, 0xFACE, gen_case, |c| {
        // Keep the cycle-stepped engine affordable.
        let c = Case {
            m: c.m.min(12),
            k: c.k.min(12),
            n: c.n.min(12),
            ..c.clone()
        };
        let cfg = cfg_of(&c);
        let emu = Emulator::new(cfg).map_err(|e| e.to_string())?;
        let a = Matrix::random_small_int(c.m, c.k, &mut data_rng);
        let w = Matrix::random_small_int(c.k, c.n, &mut data_rng);
        let wf = emu.run_gemm(&a, &w, EmulationMode::Wavefront);
        let ca = emu.run_gemm(&a, &w, EmulationMode::CycleAccurate);
        if wf.metrics != ca.metrics {
            return Err(format!("wavefront {:?} != cycle {:?}", wf.metrics, ca.metrics));
        }
        if wf.output != ca.output {
            return Err("outputs differ between engines".to_string());
        }
        Ok(())
    });
}

#[test]
fn invariant_macs_and_outputs_are_conserved() {
    check(600, 0xAB1E, gen_case, |c| {
        let g = GemmShape::new(c.m, c.k, c.n);
        let cfg = cfg_of(c);
        for m in [ws_metrics(g, &cfg), os_metrics(g, &cfg)] {
            if m.macs != g.macs() {
                return Err(format!("MACs {} != {}", m.macs, g.macs()));
            }
            let outs = (c.m * c.n) as u64;
            if m.movements.ub_out_writes != outs {
                return Err(format!(
                    "out writes {} != M*N {outs}",
                    m.movements.ub_out_writes
                ));
            }
            // Every weight is read at least once; activations at least M*K.
            if m.movements.ub_weight_reads < (c.k * c.n) as u64 {
                return Err("weights under-read".into());
            }
            if m.movements.ub_act_reads < (c.m * c.k) as u64 {
                return Err("activations under-read".into());
            }
        }
        Ok(())
    });
}

#[test]
fn invariant_utilization_bounded_and_monotone_macs() {
    check(600, 0x1111, gen_case, |c| {
        let g = GemmShape::new(c.m, c.k, c.n);
        let cfg = cfg_of(c);
        let m = ws_metrics(g, &cfg);
        let u = m.utilization(cfg.pe_count());
        if !(0.0..=1.0).contains(&u) {
            return Err(format!("utilization {u} out of range"));
        }
        // Cycles lower bound: can't beat perfect PE usage.
        let lower = (g.macs() as f64 / cfg.pe_count() as f64).floor() as u64;
        if m.cycles < lower {
            return Err(format!("cycles {} below roofline {lower}", m.cycles));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- algebra

#[derive(Debug, Clone)]
struct AlgebraCase {
    a: Metrics,
    b: Metrics,
    c: Metrics,
    s: u64,
    t: u64,
}

impl Shrink for AlgebraCase {}

fn gen_movements(rng: &mut Rng) -> MovementCounters {
    MovementCounters {
        ub_act_reads: rng.range_usize(0, 1000) as u64,
        ub_weight_reads: rng.range_usize(0, 1000) as u64,
        ub_out_writes: rng.range_usize(0, 1000) as u64,
        inter_pe_act: rng.range_usize(0, 1000) as u64,
        inter_pe_psum: rng.range_usize(0, 1000) as u64,
        inter_pe_weight: rng.range_usize(0, 1000) as u64,
        intra_pe: rng.range_usize(0, 1000) as u64,
        aa_writes: rng.range_usize(0, 1000) as u64,
        aa_reads: rng.range_usize(0, 1000) as u64,
    }
}

fn gen_metrics(rng: &mut Rng) -> Metrics {
    Metrics {
        cycles: rng.range_usize(0, 100_000) as u64,
        stall_cycles: rng.range_usize(0, 100) as u64,
        macs: rng.range_usize(0, 1_000_000) as u64,
        passes: rng.range_usize(0, 500) as u64,
        movements: gen_movements(rng),
    }
}

#[test]
fn metrics_algebra_laws() {
    check(
        600,
        0xA16EB8A,
        |rng| AlgebraCase {
            a: gen_metrics(rng),
            b: gen_metrics(rng),
            c: gen_metrics(rng),
            s: rng.range_usize(0, 64) as u64,
            t: rng.range_usize(0, 64) as u64,
        },
        |case| {
            let AlgebraCase { a, b, c, s, t } = case.clone();
            if (a + b) + c != a + (b + c) {
                return Err("addition is not associative".into());
            }
            if a + b != b + a {
                return Err("addition is not commutative".into());
            }
            if a + Metrics::default() != a {
                return Err("default is not the additive identity".into());
            }
            if a * 1 != a {
                return Err("m * 1 != m".into());
            }
            if a * 0 != Metrics::default() {
                return Err("m * 0 != identity".into());
            }
            if (a + b) * s != a * s + b * s {
                return Err("scaling does not distribute over addition".into());
            }
            if a * (s * t) != (a * s) * t {
                return Err("scalar multiplication is not associative".into());
            }
            let mut repeated = Metrics::default();
            for _ in 0..s {
                repeated += a;
            }
            if a * s != repeated {
                return Err(format!("m * {s} != {s}-fold addition"));
            }
            Ok(())
        },
    );
}

#[test]
fn workload_eval_is_linear_in_multiplicity() {
    check(300, 0x11EA_12, gen_case, |c| {
        let shape = GemmShape::new(c.m, c.k, c.n);
        let cfg = cfg_of(c);
        let mult = 1 + (c.acc % 7) as u64;
        let base = Workload::from_shapes("x1", vec![(shape, 1)]);
        let scaled = Workload::from_shapes("xn", vec![(shape, mult)]);
        if scaled.eval(&cfg) != base.eval(&cfg) * mult {
            return Err(format!("eval not linear at multiplicity {mult}"));
        }
        Ok(())
    });
}

// ------------------------------------------------- shape-major sweep core

#[derive(Debug, Clone)]
struct SweepCase {
    net: Network,
    configs: Vec<ArrayConfig>,
    threads: usize,
}

impl Shrink for SweepCase {}

fn gen_layer(rng: &mut Rng, index: usize) -> Layer {
    if rng.chance(0.25) {
        Layer::linear(
            format!("fc{index}"),
            rng.range_usize(1, 64),
            rng.range_usize(1, 32),
        )
        .with_batch(rng.range_usize(1, 4))
    } else {
        let groups = [1, 1, 2, 4][rng.range_usize(0, 3)];
        let kernel = [1, 3][rng.range_usize(0, 1)];
        Layer::conv(
            format!("c{index}"),
            SpatialDims::square(rng.range_usize(2, 14)),
            groups * rng.range_usize(1, 12),
            groups * rng.range_usize(1, 12),
            kernel,
            1,
            kernel / 2,
            groups,
        )
    }
}

fn gen_sweep_case(rng: &mut Rng) -> SweepCase {
    let mut layers = Vec::new();
    for i in 0..rng.range_usize(1, 6) {
        layers.push(gen_layer(rng, i));
        // Duplicate some layers so dedup multiplicities exceed one.
        if rng.chance(0.3) {
            let mut dup = layers[rng.range_usize(0, layers.len() - 1)].clone();
            dup.name = format!("dup{i}");
            layers.push(dup);
        }
    }
    // A random rectangular grid with a random accumulator provisioning,
    // optionally mixing in output-stationary configs (fallback path).
    let mut configs = Vec::new();
    let heights: Vec<usize> = (0..rng.range_usize(1, 3)).map(|_| rng.range_usize(1, 12)).collect();
    let widths: Vec<usize> = (0..rng.range_usize(1, 3)).map(|_| rng.range_usize(1, 12)).collect();
    let acc = rng.range_usize(1, 64);
    for &h in &heights {
        for &w in &widths {
            let cfg = ArrayConfig::new(h, w).with_acc_capacity(acc);
            if rng.chance(0.15) {
                configs.push(cfg.clone().with_dataflow(Dataflow::OutputStationary));
            }
            configs.push(cfg);
        }
    }
    SweepCase {
        net: Network::new("prop", layers),
        configs,
        threads: rng.range_usize(1, 3),
    }
}

#[test]
fn shape_major_sweep_equals_config_major_on_random_networks() {
    check(150, 0x5EEE_D0, gen_sweep_case, |case| {
        let workload = Workload::of(&case.net);
        let weights = EnergyWeights::paper();
        let fast = sweep_workload(&workload, &case.configs, &weights, case.threads);
        let naive = sweep_workload_config_major(&workload, &case.configs, &weights, case.threads);
        if fast.len() != naive.len() || fast.len() != case.configs.len() {
            return Err("point count mismatch".into());
        }
        for (i, (a, b)) in fast.iter().zip(&naive).enumerate() {
            let cfg = &case.configs[i];
            if (a.height, a.width) != (cfg.height, cfg.width) {
                return Err(format!("config order broken at {i}"));
            }
            if a.metrics != b.metrics {
                return Err(format!(
                    "metrics diverge at {cfg}: shape-major {:?} != config-major {:?}",
                    a.metrics, b.metrics
                ));
            }
            // f64 derivations must also be bit-identical (same inputs,
            // same expression).
            if a.energy != b.energy || a.utilization != b.utilization {
                return Err(format!("derived objectives diverge at {cfg}"));
            }
            // And both equal the layer-serialized network evaluation.
            let direct = workload.eval(cfg);
            if a.metrics != direct {
                return Err(format!("sweep point != direct workload eval at {cfg}"));
            }
        }
        Ok(())
    });
}

#[test]
fn shape_major_sweep_equals_config_major_on_os_dataflow() {
    // The WS path has a factored closed form the shape-major core caches;
    // output-stationary configs take the per-shape fallback. Force *every*
    // config onto the OS path (`os_metrics` is CLI-reachable via
    // `--dataflow os`) and demand byte-identical agreement anyway.
    check(150, 0x05DA_7A0, gen_sweep_case, |case| {
        let os_configs: Vec<ArrayConfig> = case
            .configs
            .iter()
            .map(|c| c.clone().with_dataflow(Dataflow::OutputStationary))
            .collect();
        let workload = Workload::of(&case.net);
        let weights = EnergyWeights::paper();
        let fast = sweep_workload(&workload, &os_configs, &weights, case.threads);
        let naive = sweep_workload_config_major(&workload, &os_configs, &weights, case.threads);
        if fast.len() != naive.len() || fast.len() != os_configs.len() {
            return Err("point count mismatch".into());
        }
        for (i, (a, b)) in fast.iter().zip(&naive).enumerate() {
            let cfg = &os_configs[i];
            if a.metrics != b.metrics {
                return Err(format!(
                    "OS metrics diverge at {cfg}: shape-major {:?} != config-major {:?}",
                    a.metrics, b.metrics
                ));
            }
            if a.energy != b.energy || a.utilization != b.utilization {
                return Err(format!("OS derived objectives diverge at {cfg}"));
            }
            // Both must equal the direct per-shape OS evaluation.
            let direct: Metrics = workload
                .shapes
                .iter()
                .map(|&(shape, mult)| os_metrics(shape, cfg) * mult)
                .sum();
            if a.metrics != direct {
                return Err(format!("sweep point != direct os_metrics sum at {cfg}"));
            }
        }
        Ok(())
    });
}

#[test]
fn graph_chain_lowering_is_byte_identical_on_random_networks() {
    // The DAG IR's degenerate chain lowering must change nothing: metrics,
    // liveness peak (= the linear-chain memory estimate) and the
    // branch-parallel schedule (= full serialization) all reduce to the
    // flat per-layer model exactly.
    use camuy::model::graph::NetworkGraph;
    use camuy::model::memory::MemoryAnalysis;
    use camuy::model::multi::MultiArrayConfig;
    use camuy::model::workload::EvalCache;

    check(60, 0x6EA9_C4A1, gen_sweep_case, |case| {
        let g = NetworkGraph::chain(&case.net);
        if !g.is_chain() {
            return Err("chain lowering is not a chain".into());
        }
        if g.to_network().layers != case.net.layers {
            return Err("chain lowering reorders layers".into());
        }
        for cfg in &case.configs {
            if g.metrics(cfg) != case.net.metrics(cfg) {
                return Err(format!("graph metrics diverge at {cfg}"));
            }
        }
        let cfg = &case.configs[0];
        let live = g.liveness(cfg);
        let mem = MemoryAnalysis::of(&case.net, cfg);
        if live.peak_bytes != mem.peak_working_set_bytes
            || live.chain_peak_bytes != mem.peak_working_set_bytes
        {
            return Err(format!(
                "chain liveness peak {} != linear estimate {}",
                live.peak_bytes, mem.peak_working_set_bytes
            ));
        }
        let cache = EvalCache::new();
        for arrays in [1usize, 2, 4] {
            let s = g.schedule(&MultiArrayConfig::new(arrays, cfg.clone()), &cache);
            if s.makespan_cycles != s.serialized_cycles {
                return Err(format!(
                    "chain schedule on {arrays} arrays: makespan {} != serialized {}",
                    s.makespan_cycles, s.serialized_cycles
                ));
            }
            if s.total != case.net.metrics(cfg) {
                return Err("scheduled totals diverge from the flat metrics".into());
            }
        }
        Ok(())
    });
}

#[test]
fn workload_eval_equals_layer_serialized_network_metrics() {
    check(150, 0xDE0D_1, gen_sweep_case, |case| {
        let workload = Workload::of(&case.net);
        for cfg in &case.configs {
            // The layer-by-layer serialization the coordinator performs.
            let by_layer: Metrics = case.net.layers.iter().map(|l| l.metrics(cfg)).sum();
            if workload.eval(cfg) != by_layer {
                return Err(format!("dedup eval != per-layer serialization at {cfg}"));
            }
        }
        Ok(())
    });
}

#[test]
fn invariant_grouped_layer_equals_group_times_single() {
    check(300, 0x9999, gen_case, |c| {
        // Build a grouped conv whose per-group GEMM is (m, k, n)-shaped:
        // use a 1x1 conv with g groups of k in / n out channels on an
        // m-pixel image (m = s*s when square; use rectangular input).
        let groups = 1 + c.acc % 5;
        let layer = Layer {
            name: "prop".into(),
            kind: camuy::model::layer::LayerKind::Conv2d {
                c_in: c.k * groups,
                c_out: c.n * groups,
                kernel: (1, 1),
                stride: (1, 1),
                padding: (0, 0),
                dilation: (1, 1),
                groups,
            },
            input: SpatialDims { h: c.m, w: 1 },
            batch: 1,
        };
        let cfg = cfg_of(c);
        let total = layer.metrics(&cfg);
        let single = ws_metrics(GemmShape::new(c.m, c.k, c.n), &cfg);
        let mut expect = camuy::metrics::Metrics::default();
        for _ in 0..groups {
            expect += single;
        }
        if total != expect {
            return Err(format!("grouped {total:?} != {groups}x single"));
        }
        Ok(())
    });
}
