//! Property tests (DESIGN.md §7): the analytic closed form, the
//! pass-iterating reference, and the functional emulator (both engines)
//! must agree *exactly* — counters, cycles, passes — across randomized
//! GEMM shapes, array geometries and accumulator capacities; and the
//! emulator's numerics must equal plain matmul.

use camuy::arch::{EmulationMode, Emulator};
use camuy::config::ArrayConfig;
use camuy::model::gemm::{os_metrics, ws_metrics, ws_metrics_ref};
use camuy::model::layer::{Layer, SpatialDims};
use camuy::model::schedule::GemmShape;
use camuy::tensor::Matrix;
use camuy::util::propcheck::{check, shrink_usize, Shrink};

#[derive(Debug, Clone)]
struct Case {
    m: usize,
    k: usize,
    n: usize,
    h: usize,
    w: usize,
    acc: usize,
}

impl Shrink for Case {
    fn shrink_candidates(&self) -> Vec<Case> {
        let mut out = Vec::new();
        let fields: [(usize, usize, fn(&Case, usize) -> Case); 6] = [
            (self.m, 1, |c, v| Case { m: v, ..c.clone() }),
            (self.k, 1, |c, v| Case { k: v, ..c.clone() }),
            (self.n, 1, |c, v| Case { n: v, ..c.clone() }),
            (self.h, 1, |c, v| Case { h: v, ..c.clone() }),
            (self.w, 1, |c, v| Case { w: v, ..c.clone() }),
            (self.acc, 1, |c, v| Case { acc: v, ..c.clone() }),
        ];
        for (cur, lo, make) in fields {
            for v in shrink_usize(cur, lo) {
                out.push(make(self, v));
            }
        }
        out
    }
}

fn gen_case(rng: &mut camuy::util::prng::Rng) -> Case {
    Case {
        m: rng.range_usize(1, 40),
        k: rng.range_usize(1, 40),
        n: rng.range_usize(1, 40),
        h: rng.range_usize(1, 12),
        w: rng.range_usize(1, 12),
        acc: rng.range_usize(1, 64),
    }
}

fn cfg_of(c: &Case) -> ArrayConfig {
    ArrayConfig::new(c.h, c.w).with_acc_capacity(c.acc)
}

#[test]
fn closed_form_equals_pass_iteration() {
    check(600, 0xC0FFEE, gen_case, |c| {
        let g = GemmShape::new(c.m, c.k, c.n);
        let fast = ws_metrics(g, &cfg_of(c));
        let slow = ws_metrics_ref(g, &cfg_of(c));
        if fast == slow {
            Ok(())
        } else {
            Err(format!("closed {fast:?}\n!= ref {slow:?}"))
        }
    });
}

#[test]
fn emulator_equals_analytic_model() {
    let mut data_rng = camuy::util::prng::Rng::new(0xDA7A);
    check(120, 0xBEEF, gen_case, |c| {
        let g = GemmShape::new(c.m, c.k, c.n);
        let cfg = cfg_of(c);
        let analytic = ws_metrics(g, &cfg);
        let emu = Emulator::new(cfg.clone()).map_err(|e| e.to_string())?;
        let a = Matrix::random_small_int(c.m, c.k, &mut data_rng);
        let w = Matrix::random_small_int(c.k, c.n, &mut data_rng);
        let res = emu.run_gemm(&a, &w, EmulationMode::Wavefront);
        if res.metrics != analytic {
            return Err(format!("emulator {:?}\n!= analytic {analytic:?}", res.metrics));
        }
        if res.output != a.matmul(&w) {
            return Err("numerics mismatch".to_string());
        }
        Ok(())
    });
}

#[test]
fn cycle_accurate_engine_equals_wavefront() {
    let mut data_rng = camuy::util::prng::Rng::new(0x51DE);
    check(40, 0xFACE, gen_case, |c| {
        // Keep the cycle-stepped engine affordable.
        let c = Case {
            m: c.m.min(12),
            k: c.k.min(12),
            n: c.n.min(12),
            ..c.clone()
        };
        let cfg = cfg_of(&c);
        let emu = Emulator::new(cfg).map_err(|e| e.to_string())?;
        let a = Matrix::random_small_int(c.m, c.k, &mut data_rng);
        let w = Matrix::random_small_int(c.k, c.n, &mut data_rng);
        let wf = emu.run_gemm(&a, &w, EmulationMode::Wavefront);
        let ca = emu.run_gemm(&a, &w, EmulationMode::CycleAccurate);
        if wf.metrics != ca.metrics {
            return Err(format!("wavefront {:?} != cycle {:?}", wf.metrics, ca.metrics));
        }
        if wf.output != ca.output {
            return Err("outputs differ between engines".to_string());
        }
        Ok(())
    });
}

#[test]
fn invariant_macs_and_outputs_are_conserved() {
    check(600, 0xAB1E, gen_case, |c| {
        let g = GemmShape::new(c.m, c.k, c.n);
        let cfg = cfg_of(c);
        for m in [ws_metrics(g, &cfg), os_metrics(g, &cfg)] {
            if m.macs != g.macs() {
                return Err(format!("MACs {} != {}", m.macs, g.macs()));
            }
            let outs = (c.m * c.n) as u64;
            if m.movements.ub_out_writes != outs {
                return Err(format!(
                    "out writes {} != M*N {outs}",
                    m.movements.ub_out_writes
                ));
            }
            // Every weight is read at least once; activations at least M*K.
            if m.movements.ub_weight_reads < (c.k * c.n) as u64 {
                return Err("weights under-read".into());
            }
            if m.movements.ub_act_reads < (c.m * c.k) as u64 {
                return Err("activations under-read".into());
            }
        }
        Ok(())
    });
}

#[test]
fn invariant_utilization_bounded_and_monotone_macs() {
    check(600, 0x1111, gen_case, |c| {
        let g = GemmShape::new(c.m, c.k, c.n);
        let cfg = cfg_of(c);
        let m = ws_metrics(g, &cfg);
        let u = m.utilization(cfg.pe_count());
        if !(0.0..=1.0).contains(&u) {
            return Err(format!("utilization {u} out of range"));
        }
        // Cycles lower bound: can't beat perfect PE usage.
        let lower = (g.macs() as f64 / cfg.pe_count() as f64).floor() as u64;
        if m.cycles < lower {
            return Err(format!("cycles {} below roofline {lower}", m.cycles));
        }
        Ok(())
    });
}

#[test]
fn invariant_grouped_layer_equals_group_times_single() {
    check(300, 0x9999, gen_case, |c| {
        // Build a grouped conv whose per-group GEMM is (m, k, n)-shaped:
        // use a 1x1 conv with g groups of k in / n out channels on an
        // m-pixel image (m = s*s when square; use rectangular input).
        let groups = 1 + c.acc % 5;
        let layer = Layer {
            name: "prop".into(),
            kind: camuy::model::layer::LayerKind::Conv2d {
                c_in: c.k * groups,
                c_out: c.n * groups,
                kernel: (1, 1),
                stride: (1, 1),
                padding: (0, 0),
                dilation: (1, 1),
                groups,
            },
            input: SpatialDims { h: c.m, w: 1 },
            batch: 1,
        };
        let cfg = cfg_of(c);
        let total = layer.metrics(&cfg);
        let single = ws_metrics(GemmShape::new(c.m, c.k, c.n), &cfg);
        let mut expect = camuy::metrics::Metrics::default();
        for _ in 0..groups {
            expect += single;
        }
        if total != expect {
            return Err(format!("grouped {total:?} != {groups}x single"));
        }
        Ok(())
    });
}
