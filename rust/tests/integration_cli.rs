//! CLI integration: every subcommand runs end to end through
//! `camuy::cli::run` on reduced grids, writing into temp directories.

use std::path::PathBuf;

fn run(args: &[&str]) -> i32 {
    let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
    camuy::cli::run(&argv)
}

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("camuy_cli_{name}"));
    let _ = std::fs::remove_dir_all(&p);
    p
}

#[test]
fn zoo_lists_models() {
    assert_eq!(run(&["zoo"]), 0);
}

#[test]
fn zoo_exports_a_network_spec() {
    assert_eq!(run(&["zoo", "--net", "alexnet", "--quiet"]), 0);
    assert_eq!(run(&["zoo", "--net", "lenet-9000", "--quiet"]), 1);
}

#[test]
fn version_flag_exits_zero() {
    assert_eq!(run(&["--version"]), 0);
}

#[test]
fn help_and_errors() {
    assert_eq!(run(&["--help"]), 0);
    assert_eq!(run(&[]), 2);
    assert_eq!(run(&["frobnicate"]), 2);
    assert_eq!(run(&["sweep"]), 1); // missing --net
    assert_eq!(run(&["emulate", "--net", "nope"]), 1);
    assert_eq!(run(&["emulate", "--net", "alexnet", "--height", "0"]), 1);
    assert_eq!(run(&["sweep", "--net", "alexnet", "--grid", "bogus"]), 1);
}

#[test]
fn emulate_variants() {
    assert_eq!(run(&["emulate", "--net", "alexnet", "--quiet"]), 0);
    assert_eq!(
        run(&["emulate", "--net", "alexnet", "--json", "--quiet"]),
        0
    );
    assert_eq!(
        run(&["emulate", "--net", "alexnet", "--per-layer", "--batch", "4", "--quiet"]),
        0
    );
    assert_eq!(
        run(&["emulate", "--net", "mobilenetv3l", "--arrays", "4", "--quiet"]),
        0
    );
    assert_eq!(
        run(&["emulate", "--net", "alexnet", "--dataflow", "os", "--quiet"]),
        0
    );
    assert_eq!(
        run(&["emulate", "--net", "alexnet", "--energy-model", "dally14nm", "--quiet"]),
        0
    );
}

#[test]
fn sweep_writes_outputs() {
    let out = tmp("sweep");
    assert_eq!(
        run(&[
            "sweep", "--net", "alexnet", "--grid", "smoke", "--out",
            out.to_str().unwrap(), "--quiet"
        ]),
        0
    );
    assert!(out.join("fig2_alexnet.energy.csv").exists());
    let _ = std::fs::remove_dir_all(&out);
}

#[test]
fn pareto_and_robust_and_equal_pe() {
    let out = tmp("pareto");
    assert_eq!(
        run(&[
            "pareto", "--net", "alexnet", "--grid", "smoke", "--out",
            out.to_str().unwrap(), "--quiet"
        ]),
        0
    );
    assert!(out.join("fig3_alexnet.energy_pareto.csv").exists());

    assert_eq!(
        run(&["robust", "--grid", "smoke", "--out", out.to_str().unwrap(), "--quiet"]),
        0
    );
    assert!(out.join("fig5_robust_pareto.csv").exists());

    assert_eq!(
        run(&[
            "equal-pe", "--grid", "smoke", "--budget", "4096", "--out",
            out.to_str().unwrap(), "--quiet"
        ]),
        0
    );
    assert!(out.join("fig6_equal_pe.csv").exists());
    let _ = std::fs::remove_dir_all(&out);
}

#[test]
fn figures_produces_the_full_set_on_smoke_grid() {
    let out = tmp("figures");
    assert_eq!(
        run(&["figures", "--grid", "smoke", "--out", out.to_str().unwrap(), "--quiet"]),
        0
    );
    for f in [
        "fig2_resnet152.energy.csv",
        "fig3_resnet152.energy_pareto.csv",
        "fig4_all.txt",
        "fig5_robust_pareto.csv",
        "fig6_equal_pe.csv",
        "fig7_liveness_energy.csv",
    ] {
        assert!(out.join(f).exists(), "{f} missing");
    }
    let _ = std::fs::remove_dir_all(&out);
}

#[test]
fn memory_reports_spills() {
    assert_eq!(run(&["memory", "--net", "vgg16", "--quiet"]), 0);
    assert_eq!(run(&["memory", "--net", "resnet152", "--quiet"]), 0);
    assert_eq!(run(&["memory", "--net", "resnet152", "--graph", "--quiet"]), 0);
    assert_eq!(run(&["memory", "--quiet"]), 1); // --net required
}

#[test]
fn graph_reports_connectivity() {
    assert_eq!(run(&["graph", "--net", "resnet50", "--quiet"]), 0);
    assert_eq!(
        run(&["graph", "--net", "googlenet", "--arrays", "4", "--json", "--quiet"]),
        0
    );
    assert_eq!(run(&["graph", "--net", "alexnet", "--batch", "2", "--quiet"]), 0);
    assert_eq!(run(&["graph", "--net", "lenet-9000", "--quiet"]), 1);
    assert_eq!(run(&["graph", "--quiet"]), 1); // --net required
    let out = tmp("graph");
    assert_eq!(
        run(&[
            "graph", "--net", "densenet121", "--out", out.to_str().unwrap(), "--quiet"
        ]),
        0
    );
    assert!(out.join("graph_densenet121.liveness.csv").exists());
    let _ = std::fs::remove_dir_all(&out);
}

#[test]
fn verify_runs_when_artifacts_exist() {
    if !camuy::runtime::default_artifact_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    assert_eq!(run(&["verify", "--quiet"]), 0);
}
