//! Property tests for the event-driven simulator (DESIGN.md §13).
//!
//! The simulator is a second, independent oracle for the analytic chain:
//! its timing emerges from bounded channels and context initiation
//! intervals, not from the closed forms — so every equality below is a
//! real cross-check, not a tautology. Proven here, on random shapes,
//! geometries and accumulator capacities:
//!
//! * simulated cycles, stalls, passes and **every** `MovementCounters`
//!   field equal `ws_metrics` / `os_metrics` exactly, both dataflows,
//!   including degenerate 1xN / Nx1 arrays;
//! * the measured peak SDS FIFO depth equals its closed form
//!   (`sim::gemm_fifo_depth`) and the functional emulator's report;
//! * the Wavefront and CycleAccurate emulator engines agree on output,
//!   metrics and FIFO depth;
//! * a whole-network simulation (traced or not) equals the analytic
//!   `Workload` evaluation and produces a valid Perfetto document.

use camuy::arch::{EmulationMode, Emulator};
use camuy::config::{ArrayConfig, Dataflow};
use camuy::model::gemm::{os_metrics, ws_metrics};
use camuy::model::schedule::GemmShape;
use camuy::model::workload::Workload;
use camuy::sim::{gemm_fifo_depth, network_fifo_depth, simulate_gemm, simulate_network};
use camuy::sim::{SimOptions, TraceSink};
use camuy::tensor::Matrix;
use camuy::util::json::Json;
use camuy::util::prng::Rng;
use camuy::util::propcheck::{check, shrink_usize, Shrink};

#[derive(Debug, Clone)]
struct Case {
    m: usize,
    k: usize,
    n: usize,
    h: usize,
    w: usize,
    acc: usize,
}

impl Shrink for Case {
    fn shrink_candidates(&self) -> Vec<Case> {
        let mut out = Vec::new();
        let fields: [(usize, usize, fn(&Case, usize) -> Case); 6] = [
            (self.m, 1, |c, v| Case { m: v, ..c.clone() }),
            (self.k, 1, |c, v| Case { k: v, ..c.clone() }),
            (self.n, 1, |c, v| Case { n: v, ..c.clone() }),
            (self.h, 1, |c, v| Case { h: v, ..c.clone() }),
            (self.w, 1, |c, v| Case { w: v, ..c.clone() }),
            (self.acc, 1, |c, v| Case { acc: v, ..c.clone() }),
        ];
        for (cur, lo, make) in fields {
            for v in shrink_usize(cur, lo) {
                out.push(make(self, v));
            }
        }
        out
    }
}

fn gen_case(rng: &mut Rng) -> Case {
    Case {
        m: rng.range_usize(1, 64),
        k: rng.range_usize(1, 96),
        n: rng.range_usize(1, 96),
        h: rng.range_usize(1, 12),
        w: rng.range_usize(1, 12),
        acc: rng.range_usize(1, 48),
    }
}

fn cfg_of(c: &Case, df: Dataflow) -> ArrayConfig {
    ArrayConfig::new(c.h, c.w)
        .with_acc_capacity(c.acc)
        .with_dataflow(df)
}

#[test]
fn sim_equals_ws_closed_form_exactly() {
    check(300, 0x51B0_0001, gen_case, |c| {
        let g = GemmShape::new(c.m, c.k, c.n);
        let cfg = cfg_of(c, Dataflow::WeightStationary);
        let sim = simulate_gemm(g, &cfg, &mut TraceSink::Off);
        let analytic = ws_metrics(g, &cfg);
        if sim.metrics == analytic {
            Ok(())
        } else {
            Err(format!("sim {:?}\n!= analytic {analytic:?}", sim.metrics))
        }
    });
}

#[test]
fn sim_equals_os_closed_form_exactly() {
    check(300, 0x51B0_0002, gen_case, |c| {
        let g = GemmShape::new(c.m, c.k, c.n);
        let cfg = cfg_of(c, Dataflow::OutputStationary);
        let sim = simulate_gemm(g, &cfg, &mut TraceSink::Off);
        let analytic = os_metrics(g, &cfg);
        if sim.metrics == analytic {
            Ok(())
        } else {
            Err(format!("sim {:?}\n!= analytic {analytic:?}", sim.metrics))
        }
    });
}

#[test]
fn degenerate_arrays_match_both_dataflows() {
    for (h, w) in [(1, 24), (24, 1), (1, 1), (2, 1), (1, 2)] {
        for (m, k, n) in [(1, 1, 1), (13, 7, 19), (5, 40, 3)] {
            let g = GemmShape::new(m, k, n);
            let c = Case { m, k, n, h, w, acc: 16 };
            let ws = cfg_of(&c, Dataflow::WeightStationary);
            let os = cfg_of(&c, Dataflow::OutputStationary);
            let sim_ws = simulate_gemm(g, &ws, &mut TraceSink::Off);
            let sim_os = simulate_gemm(g, &os, &mut TraceSink::Off);
            assert_eq!(sim_ws.metrics, ws_metrics(g, &ws), "{h}x{w} {m}x{k}x{n}");
            assert_eq!(sim_os.metrics, os_metrics(g, &os), "{h}x{w} {m}x{k}x{n}");
        }
    }
}

#[test]
fn fifo_depth_matches_closed_form_and_emulator() {
    check(120, 0x51B0_0003, gen_case, |c| {
        let g = GemmShape::new(c.m, c.k, c.n);
        for df in [Dataflow::WeightStationary, Dataflow::OutputStationary] {
            let cfg = cfg_of(c, df);
            let sim = simulate_gemm(g, &cfg, &mut TraceSink::Off);
            let closed = gemm_fifo_depth(g, &cfg);
            if sim.max_fifo_depth != closed {
                return Err(format!(
                    "{df:?}: sim depth {} != closed form {closed}",
                    sim.max_fifo_depth
                ));
            }
            let emu = Emulator::new(cfg.clone()).map_err(|e| e.to_string())?;
            let mut rng = Rng::new(0xDA7A);
            let a = Matrix::random_small_int(c.m, c.k, &mut rng);
            let w = Matrix::random_small_int(c.k, c.n, &mut rng);
            let res = emu.run_gemm(&a, &w, EmulationMode::Wavefront);
            if res.max_fifo_depth != closed {
                return Err(format!(
                    "{df:?}: emulator depth {} != closed form {closed}",
                    res.max_fifo_depth
                ));
            }
        }
        Ok(())
    });
}

fn gen_small_case(rng: &mut Rng) -> Case {
    Case {
        m: rng.range_usize(1, 12),
        k: rng.range_usize(1, 12),
        n: rng.range_usize(1, 12),
        h: rng.range_usize(1, 6),
        w: rng.range_usize(1, 6),
        acc: rng.range_usize(1, 16),
    }
}

#[test]
fn wavefront_equals_cycle_accurate() {
    let mut data_rng = Rng::new(0xDA7A);
    check(60, 0x51B0_0004, gen_small_case, |c| {
        let cfg = cfg_of(c, Dataflow::WeightStationary);
        let emu = Emulator::new(cfg).map_err(|e| e.to_string())?;
        let a = Matrix::random_small_int(c.m, c.k, &mut data_rng);
        let w = Matrix::random_small_int(c.k, c.n, &mut data_rng);
        let fast = emu.run_gemm(&a, &w, EmulationMode::Wavefront);
        let slow = emu.run_gemm(&a, &w, EmulationMode::CycleAccurate);
        if fast.output != slow.output {
            return Err("engines disagree on the output matrix".to_string());
        }
        if fast.metrics != slow.metrics {
            return Err(format!(
                "metrics diverge: wavefront {:?}\n!= cycle-accurate {:?}",
                fast.metrics, slow.metrics
            ));
        }
        if fast.max_fifo_depth != slow.max_fifo_depth {
            return Err(format!(
                "fifo depth diverges: {} != {}",
                fast.max_fifo_depth, slow.max_fifo_depth
            ));
        }
        // Both engines must also match the simulator's independent timing.
        let g = GemmShape::new(c.m, c.k, c.n);
        let cfg = cfg_of(c, Dataflow::WeightStationary);
        let sim = simulate_gemm(g, &cfg, &mut TraceSink::Off);
        if sim.metrics != fast.metrics {
            return Err(format!(
                "sim {:?}\n!= emulator {:?}",
                sim.metrics, fast.metrics
            ));
        }
        Ok(())
    });
}

#[test]
fn network_sim_equals_analytic_eval_both_dataflows() {
    for name in ["alexnet", "mobilenetv3l"] {
        let net = camuy::nets::build(name).unwrap();
        for df in [Dataflow::WeightStationary, Dataflow::OutputStationary] {
            let cfg = ArrayConfig::new(24, 40)
                .with_acc_capacity(512)
                .with_dataflow(df);
            let sim = simulate_network(&net, &cfg, 2, &SimOptions::default());
            let analytic = Workload::of(&net).eval(&cfg);
            assert_eq!(sim.total, analytic, "{name} {df:?}");
            assert_eq!(
                sim.max_fifo_depth,
                network_fifo_depth(&net, &cfg),
                "{name} {df:?}"
            );
        }
    }
}

#[test]
fn traced_network_produces_valid_perfetto_document() {
    let net = camuy::nets::build("alexnet").unwrap();
    let cfg = ArrayConfig::new(32, 32);
    let plain = simulate_network(&net, &cfg, 1, &SimOptions::default());
    let traced = simulate_network(&net, &cfg, 2, &SimOptions::traced(1 << 15));
    // Tracing is observation only: metrics are bit-identical.
    assert_eq!(plain.total, traced.total);
    let doc = traced.perfetto().to_string_compact();
    let parsed = Json::parse(&doc).expect("trace document parses as JSON");
    let events = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(!events.is_empty());
    for track in [
        "Weight Fetcher",
        "Systolic Data Setup",
        "PE Array",
        "Accumulator Array",
        "Unified Buffer",
    ] {
        assert!(doc.contains(track), "missing track {track}");
    }
    for counter in ["SDS occupancy (rows)", "UB residency (bytes)", "PE utilization"] {
        assert!(doc.contains(counter), "missing counter {counter}");
    }
}
