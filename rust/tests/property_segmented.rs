//! Property tests for the segmented piecewise-constant sweep plans
//! (DESIGN.md §10/§11): on random networks, dense step-1 grids,
//! degenerate axes and both dataflows, the segmented core must be
//! **byte-identical** to the config-major oracle (and to the shape-major
//! intermediate core) — metrics, energy and utilization alike — and the
//! seeding path must plant exactly `ws_metrics` / `os_metrics` into the
//! memo table. Since §11 the output-stationary dataflow sweeps through
//! its own segmented plan ([`SegmentedOsPlan`]) rather than the
//! cell-by-cell fallback, so the forced-OS cases below exercise that
//! plan end to end. Since §12 the segmented plans assemble cells through
//! fused multi-lane kernels over lane-padded tables, so every identity
//! here is four-way: vectorized blocked == scalar segmented ==
//! shape-major == config-major, with dedicated cases at the lane
//! boundaries (`s % 8 ∈ {0, 1, 7}`).

use camuy::config::{ArrayConfig, Dataflow, EnergyWeights};
use camuy::metrics::Metrics;
use camuy::model::gemm::{gemm_metrics, os_metrics, DOT_LANES};
use camuy::model::layer::{Layer, SpatialDims};
use camuy::model::network::Network;
use camuy::model::schedule::GemmShape;
use camuy::model::workload::{EvalCache, Workload};
use camuy::sweep::plan::{PlanCache, SegmentedOsPlan, SegmentedWsPlan};
use camuy::sweep::runner::{
    seed_workload_planned, sweep_workload_config_major, sweep_workload_segmented,
    sweep_workload_segmented_scalar, sweep_workload_shape_major,
};
use camuy::util::prng::Rng;
use camuy::util::propcheck::{check, Shrink};

#[derive(Debug, Clone)]
struct Case {
    net: Network,
    configs: Vec<ArrayConfig>,
    threads: usize,
}

impl Shrink for Case {}

fn gen_layer(rng: &mut Rng, index: usize) -> Layer {
    if rng.chance(0.25) {
        Layer::linear(
            format!("fc{index}"),
            rng.range_usize(1, 64),
            rng.range_usize(1, 32),
        )
        .with_batch(rng.range_usize(1, 4))
    } else {
        let groups = [1, 1, 2, 4][rng.range_usize(0, 3)];
        let kernel = [1, 3][rng.range_usize(0, 1)];
        Layer::conv(
            format!("c{index}"),
            SpatialDims::square(rng.range_usize(2, 14)),
            groups * rng.range_usize(1, 12),
            groups * rng.range_usize(1, 12),
            kernel,
            1,
            kernel / 2,
            groups,
        )
    }
}

fn gen_net(rng: &mut Rng) -> Network {
    let mut layers = Vec::new();
    for i in 0..rng.range_usize(1, 5) {
        layers.push(gen_layer(rng, i));
        if rng.chance(0.3) {
            let mut dup = layers[rng.range_usize(0, layers.len() - 1)].clone();
            dup.name = format!("dup{i}");
            layers.push(dup);
        }
    }
    Network::new("prop", layers)
}

/// A dense step-1 grid (the segmented plan's headline axis shape) with a
/// random accumulator provisioning, a sprinkle of OS-dataflow configs
/// (fallback path), a second accumulator capacity (plan grouping) and
/// duplicated cells (router robustness).
fn gen_dense_case(rng: &mut Rng) -> Case {
    let net = gen_net(rng);
    let lo = rng.range_usize(1, 3);
    let hi = lo + rng.range_usize(3, 24);
    let acc = rng.range_usize(1, 64);
    let mut configs = Vec::new();
    for h in lo..=hi {
        for w in lo..=hi {
            let cfg = ArrayConfig::new(h, w).with_acc_capacity(acc);
            if rng.chance(0.1) {
                configs.push(cfg.clone().with_dataflow(Dataflow::OutputStationary));
            }
            if rng.chance(0.05) {
                configs.push(cfg.clone().with_acc_capacity(acc + 7));
            }
            configs.push(cfg);
        }
    }
    // Duplicate a random prefix so repeated cells exercise the router.
    let dups = rng.range_usize(0, 4).min(configs.len());
    let prefix: Vec<ArrayConfig> = configs[..dups].to_vec();
    configs.extend(prefix);
    Case {
        net,
        configs,
        threads: rng.range_usize(1, 3),
    }
}

fn assert_three_way_identical(case: &Case) -> Result<(), String> {
    let workload = Workload::of(&case.net);
    let weights = EnergyWeights::paper();
    let seg = sweep_workload_segmented(&workload, &case.configs, &weights, case.threads);
    let sc = sweep_workload_segmented_scalar(&workload, &case.configs, &weights, case.threads, None);
    let sm = sweep_workload_shape_major(&workload, &case.configs, &weights, case.threads);
    let cm = sweep_workload_config_major(&workload, &case.configs, &weights, case.threads);
    if seg.len() != case.configs.len()
        || sc.len() != seg.len()
        || sm.len() != seg.len()
        || cm.len() != seg.len()
    {
        return Err("point count mismatch".into());
    }
    for (i, cfg) in case.configs.iter().enumerate() {
        if (seg[i].height, seg[i].width) != (cfg.height, cfg.width) {
            return Err(format!("config order broken at {i}"));
        }
        if seg[i].metrics != cm[i].metrics {
            return Err(format!(
                "segmented diverges from config-major at {cfg}: {:?} != {:?}",
                seg[i].metrics, cm[i].metrics
            ));
        }
        if seg[i].metrics != sm[i].metrics {
            return Err(format!("segmented diverges from shape-major at {cfg}"));
        }
        if seg[i].metrics != sc[i].metrics {
            return Err(format!(
                "vectorized blocked core diverges from the scalar segmented \
                 rung at {cfg}: {:?} != {:?}",
                seg[i].metrics, sc[i].metrics
            ));
        }
        // f64 derivations must be bit-identical too (same integer inputs,
        // same expression).
        if seg[i].energy != cm[i].energy || seg[i].utilization != cm[i].utilization {
            return Err(format!("derived objectives diverge at {cfg}"));
        }
        if sc[i].energy != cm[i].energy || sc[i].utilization != cm[i].utilization {
            return Err(format!("scalar-rung derived objectives diverge at {cfg}"));
        }
    }
    Ok(())
}

#[test]
fn segmented_equals_oracle_on_dense_step1_grids() {
    check(60, 0x5E6_3D, gen_dense_case, assert_three_way_identical);
}

#[test]
fn segmented_equals_oracle_on_forced_os_dataflow() {
    check(40, 0x05DA_7A1, gen_dense_case, |case| {
        let os = Case {
            net: case.net.clone(),
            configs: case
                .configs
                .iter()
                .map(|c| c.clone().with_dataflow(Dataflow::OutputStationary))
                .collect(),
            threads: case.threads,
        };
        assert_three_way_identical(&os)
    });
}

#[test]
fn segmented_handles_degenerate_axes() {
    let mut rng = Rng::new(0xDE6E_11);
    for _ in 0..30 {
        let net = gen_net(&mut rng);
        let acc = rng.range_usize(1, 4096);
        let degenerate: Vec<Vec<ArrayConfig>> = vec![
            // A single cell.
            vec![ArrayConfig::new(5, 3).with_acc_capacity(acc)],
            // Height 1: every row factor degenerates to K tiles.
            (1..=9)
                .map(|w| ArrayConfig::new(1, w).with_acc_capacity(acc))
                .collect(),
            // Width 1 column arrays.
            (1..=9)
                .map(|h| ArrayConfig::new(h, 1).with_acc_capacity(acc))
                .collect(),
            // Axis values larger than every GEMM dimension: single-tile
            // territory, where the tail class is the whole operand.
            vec![
                ArrayConfig::new(4096, 2048).with_acc_capacity(acc),
                ArrayConfig::new(8192, 2048).with_acc_capacity(acc),
                ArrayConfig::new(1 << 19, 1 << 19).with_acc_capacity(acc),
            ],
        ];
        for configs in degenerate {
            let case = Case {
                net: net.clone(),
                configs,
                threads: 1,
            };
            if let Err(e) = assert_three_way_identical(&case) {
                panic!("degenerate axes diverged: {e}");
            }
        }
    }
}

#[test]
fn planned_seeding_plants_exact_per_shape_metrics() {
    let mut rng = Rng::new(0x5EED_CA);
    for _ in 0..20 {
        let case = gen_dense_case(&mut rng);
        let workload = Workload::of(&case.net);
        let cache = EvalCache::new();
        let plans = PlanCache::new();
        seed_workload_planned(&workload, &case.configs, case.threads, &cache, Some(&plans));
        for cfg in &case.configs {
            for &(shape, _) in &workload.shapes {
                if !cache.contains(shape, cfg) {
                    panic!("missing seed for {shape:?} at {cfg}");
                }
            }
            let direct: Metrics = workload
                .shapes
                .iter()
                .map(|&(shape, mult)| gemm_metrics(shape, cfg) * mult)
                .sum();
            assert_eq!(workload.eval_cached(cfg, &cache), direct, "at {cfg}");
        }
    }
}

#[test]
fn os_plan_cells_equal_the_os_metrics_oracle() {
    // The OS segment algebra against the closed-form oracle, per shape
    // and per workload cell, on random networks and dense axes — the OS
    // mirror of `plan_probe_equals_direct_eval_on_random_networks`.
    let mut rng = Rng::new(0x05_0A_AC);
    for _ in 0..20 {
        let net = gen_net(&mut rng);
        let workload = Workload::of(&net);
        let heights: Vec<usize> = (1..=20).collect();
        let widths: Vec<usize> = (3..=17).collect();
        let plan = SegmentedOsPlan::new(&workload, &heights, &widths);
        for (hi, &h) in heights.iter().enumerate() {
            for (wi, &w) in widths.iter().enumerate() {
                let cfg = ArrayConfig::new(h, w).with_dataflow(Dataflow::OutputStationary);
                // Workload cell = Σ multiplicity × oracle.
                let direct: Metrics = workload
                    .shapes
                    .iter()
                    .map(|&(shape, mult)| os_metrics(shape, &cfg) * mult)
                    .sum();
                assert_eq!(plan.cell(hi, wi), direct, "OS cell at ({h}, {w})");
                // Per-shape seeding values = the oracle exactly.
                for (si, &(shape, _)) in workload.shapes.iter().enumerate() {
                    assert_eq!(
                        plan.shape_cell(si, hi, wi),
                        os_metrics(shape, &cfg),
                        "OS shape cell {shape:?} at ({h}, {w})"
                    );
                }
            }
        }
        assert_eq!(plan.probe(21, 3), None);
    }
}

#[test]
fn fused_kernels_agree_across_lane_boundaries() {
    // Distinct-shape counts with s % 8 ∈ {0, 1, 7} straddle the 8-lane
    // kernel width (DESIGN.md §12): full lane blocks only, one element
    // past a block, and one short of a block. The zero padding in the
    // lane-strided tables must stay inert — the fused cell, the scalar
    // combine and the direct oracle agree on every cell, both dataflows,
    // including degenerate and larger-than-every-GEMM axes.
    let mut rng = Rng::new(0x1A9E_0B);
    let heights: Vec<usize> = vec![1, 2, 3, 5, 8, 13, 4096];
    let widths: Vec<usize> = vec![1, 4, 7, 2048];
    for &s in &[1usize, 7, 8, 9, 15, 16, 17] {
        // Strictly distinct K dimensions (spacing 8 > the random offset)
        // so deduplication cannot collapse the shape count below `s`.
        let pairs: Vec<(GemmShape, u64)> = (0..s)
            .map(|i| {
                let k = 3 + 8 * i + rng.range_usize(0, 5);
                (
                    GemmShape::new(rng.range_usize(1, 40), k, rng.range_usize(1, 24)),
                    rng.range_usize(1, 4) as u64,
                )
            })
            .collect();
        let workload = Workload::from_shapes(format!("lanes{s}"), pairs);
        assert_eq!(workload.distinct(), s, "distinct K values must not dedup");

        let acc = rng.range_usize(1, 64);
        let ws = SegmentedWsPlan::new(&workload, &heights, &widths, acc);
        assert_eq!(ws.lane_stride() % DOT_LANES, 0, "stride not lane-padded");
        assert!(ws.lane_stride() >= s && ws.lane_stride() < s + DOT_LANES);
        let os = SegmentedOsPlan::new(&workload, &heights, &widths);
        assert_eq!(os.lane_stride(), ws.lane_stride());
        for (hi, &h) in heights.iter().enumerate() {
            for (wi, &w) in widths.iter().enumerate() {
                let cfg = ArrayConfig::new(h, w).with_acc_capacity(acc);
                let fused = ws.cell(hi, wi);
                assert_eq!(fused, ws.cell_scalar(hi, wi), "WS scalar ({h}, {w}) s={s}");
                assert_eq!(fused, workload.eval(&cfg), "WS oracle ({h}, {w}) s={s}");

                let os_cfg = cfg.with_dataflow(Dataflow::OutputStationary);
                let direct: Metrics = workload
                    .shapes
                    .iter()
                    .map(|&(shape, mult)| os_metrics(shape, &os_cfg) * mult)
                    .sum();
                let fused_os = os.cell(hi, wi);
                assert_eq!(fused_os, os.cell_scalar(hi, wi), "OS scalar ({h}, {w}) s={s}");
                assert_eq!(fused_os, direct, "OS oracle ({h}, {w}) s={s}");
            }
        }
    }
}

#[test]
fn plan_probe_equals_direct_eval_on_random_networks() {
    let mut rng = Rng::new(0x960B_E5);
    for _ in 0..20 {
        let net = gen_net(&mut rng);
        let workload = Workload::of(&net);
        let heights: Vec<usize> = (1..=20).collect();
        let widths: Vec<usize> = (3..=17).collect();
        let acc = rng.range_usize(1, 128);
        let plan = SegmentedWsPlan::new(&workload, &heights, &widths, acc);
        for &h in &heights {
            for &w in &widths {
                let cfg = ArrayConfig::new(h, w).with_acc_capacity(acc);
                assert_eq!(plan.probe(h, w), Some(workload.eval(&cfg)));
            }
        }
        assert_eq!(plan.probe(21, 3), None);
    }
}
