//! End-to-end coverage of the typed query API: JSON network ingestion,
//! the long-lived engine's shared memo table, batched evaluation, and the
//! JSON-lines serve loop (stdin-shaped and TCP).

use camuy::api::{
    ApiError, Engine, EvalRequest, EvalResponse, ParetoRequest, ServeOptions, StatsRequest,
    SweepRequest, SweepSpec,
};
use camuy::config::{ArrayConfig, ConfigError};
use camuy::coordinator::Coordinator;
use camuy::model::layer::{Layer, SpatialDims};
use camuy::model::network::Network;
use camuy::model::workload::Workload;
use camuy::telemetry::{ReqKind, TelemetrySnapshot};
use camuy::util::json::Json;

/// A 16x16 conv stack plus a classifier head: 8*16*16 = 2048 features.
const TINY_SPEC: &str = r#"{
  "name": "tinynet",
  "layers": [
    {"op": "conv2d", "name": "c1", "input": {"h": 16, "w": 16},
     "c_in": 3, "c_out": 8, "kernel": 3, "stride": 1, "padding": 1},
    {"op": "conv2d", "name": "c2", "input": {"h": 16, "w": 16},
     "c_in": 8, "c_out": 8, "kernel": [3, 3], "padding": [1, 1], "groups": 2},
    {"op": "linear", "name": "fc", "in_features": 2048, "out_features": 10}
  ]
}"#;

/// The same network built programmatically.
fn tiny_programmatic() -> Network {
    Network::new(
        "tinynet",
        vec![
            Layer::conv("c1", SpatialDims::square(16), 3, 8, 3, 1, 1, 1),
            Layer::conv("c2", SpatialDims::square(16), 8, 8, 3, 1, 1, 2),
            Layer::linear("fc", 2048, 10),
        ],
    )
}

/// Run the serve loop over a request string, returning parsed responses.
fn serve_str(engine: &Engine, input: &str, opts: &ServeOptions) -> Vec<Json> {
    let mut out: Vec<u8> = Vec::new();
    camuy::api::serve(engine, input.as_bytes(), &mut out, opts).unwrap();
    String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .collect()
}

#[test]
fn registered_json_network_matches_programmatic_workload() {
    let engine = Engine::new();
    let reg = engine.register_network_str(TINY_SPEC).unwrap();
    assert_eq!(reg.name, "tinynet");
    assert_eq!(reg.layers, 3);
    assert!(!reg.replaced);

    let reference = tiny_programmatic();
    assert_eq!(reg.params, reference.params());
    assert_eq!(reg.macs, reference.macs());

    // Identical workload IR…
    let registered = engine.resolve("tinynet", None).unwrap();
    assert_eq!(
        Workload::of(&registered).shapes,
        Workload::of(&reference).shapes
    );

    // …and identical metrics through the engine.
    let cfg = ArrayConfig::new(32, 16);
    let resp = engine
        .eval(&EvalRequest::new("tinynet", cfg.clone()))
        .unwrap();
    assert_eq!(*resp.total(), reference.metrics(&cfg));

    // Re-registering the same name reports the replacement.
    assert!(engine.register_network_str(TINY_SPEC).unwrap().replaced);
    // Zoo names are reserved.
    let clash = TINY_SPEC.replace("tinynet", "alexnet");
    assert!(matches!(
        engine.register_network_str(&clash),
        Err(ApiError::InvalidNetwork(_))
    ));
}

#[test]
fn user_network_store_is_bounded() {
    let engine = Engine::new();
    for i in 0..camuy::api::MAX_USER_NETWORKS {
        let spec = TINY_SPEC.replace("tinynet", &format!("n{i}"));
        engine.register_network_str(&spec).unwrap();
    }
    let overflow = TINY_SPEC.replace("tinynet", "one-too-many");
    assert!(matches!(
        engine.register_network_str(&overflow),
        Err(ApiError::InvalidNetwork(_))
    ));
    // Replacing an existing name is still allowed at capacity.
    let again = TINY_SPEC.replace("tinynet", "n0");
    assert!(engine.register_network_str(&again).unwrap().replaced);
}

#[test]
fn engine_cache_is_shared_across_requests() {
    let engine = Engine::new();
    let req = EvalRequest::new("alexnet", ArrayConfig::new(32, 32));
    let a = engine.eval(&req).unwrap();
    let misses = engine.cache().misses();
    let hits = engine.cache().hits();
    assert!(misses > 0);
    let b = engine.eval(&req).unwrap();
    assert_eq!(engine.cache().misses(), misses, "repeat query recomputed");
    assert!(engine.cache().hits() > hits);
    assert_eq!(a.total(), b.total());
}

#[test]
fn sweep_and_pareto_requests_reuse_the_plan_cache() {
    let engine = Engine::new();
    let req = SweepRequest {
        net: "alexnet".to_string(),
        spec: SweepSpec::smoke(),
    };
    let a = engine.sweep(&req).unwrap();
    assert_eq!(engine.plans().len(), 1);
    let misses = engine.plans().misses();
    let b = engine.sweep(&req).unwrap();
    assert_eq!(engine.plans().misses(), misses, "repeat sweep rebuilt its plan");
    assert!(engine.plans().hits() > 0);
    assert_eq!(a.sweep.points.len(), b.sweep.points.len());
    for (x, y) in a.sweep.points.iter().zip(&b.sweep.points) {
        assert_eq!(x.metrics, y.metrics);
        assert_eq!(x.energy, y.energy);
    }
    // A Pareto request on the same (workload, grid, acc) hits the same
    // plan — NSGA-II genome probes run through segment lookup.
    let preq = ParetoRequest {
        net: "alexnet".to_string(),
        spec: SweepSpec::smoke(),
        params: camuy::pareto::nsga2::Nsga2Params {
            population: 8,
            generations: 4,
            ..Default::default()
        },
    };
    let len = engine.plans().len();
    let hits = engine.plans().hits();
    let d = engine.pareto(&preq).unwrap();
    assert!(!d.energy_front.is_empty());
    assert_eq!(engine.plans().len(), len, "pareto built a redundant plan");
    assert!(engine.plans().hits() > hits);
}

#[test]
fn reregistration_changes_the_plan_fingerprint() {
    let engine = Engine::new();
    engine.register_network_str(TINY_SPEC).unwrap();
    let req = SweepRequest {
        net: "tinynet".to_string(),
        spec: SweepSpec::smoke(),
    };
    let first = engine.sweep(&req).unwrap();
    let plans_before = engine.plans().len();
    // Same name, different layer geometry: the workload fingerprint in the
    // plan key changes, so the old plan can never serve the new network.
    let altered = TINY_SPEC.replace("\"c_out\": 8", "\"c_out\": 6");
    assert_ne!(altered, TINY_SPEC);
    engine.register_network_str(&altered).unwrap();
    let second = engine.sweep(&req).unwrap();
    assert!(engine.plans().len() > plans_before, "stale plan was reused");
    assert_ne!(
        first.sweep.points[0].metrics, second.sweep.points[0].metrics,
        "re-registered network must evaluate differently"
    );
}

#[test]
fn eval_batch_matches_individual_and_seeds_the_cache() {
    let engine = Engine::new();
    let reqs: Vec<EvalRequest> = [16usize, 24, 32, 16]
        .iter()
        .map(|&h| EvalRequest::new("mobilenetv3l", ArrayConfig::new(h, 16)))
        .collect();
    let batch = engine.eval_batch(&reqs, 2);
    assert_eq!(batch.len(), reqs.len());
    let fresh = Engine::new();
    for (res, req) in batch.iter().zip(&reqs) {
        let single = fresh.eval(req).unwrap();
        assert_eq!(res.as_ref().unwrap().total(), single.total());
    }
    // The batch pass seeded (shape, config) entries the per-request pass
    // then consumed as hits.
    assert!(engine.cache().len() > 0);
    assert!(engine.cache().hits() > 0);
    // A repeat batch is answered entirely from the memo table.
    let misses = engine.cache().misses();
    let len = engine.cache().len();
    let again = engine.eval_batch(&reqs, 2);
    assert_eq!(engine.cache().misses(), misses);
    assert_eq!(engine.cache().len(), len);
    for (a, b) in again.iter().zip(&batch) {
        assert_eq!(a.as_ref().unwrap().total(), b.as_ref().unwrap().total());
    }
}

#[test]
fn typed_errors_surface_through_engine_and_wire() {
    let engine = Engine::new();
    match engine.eval(&EvalRequest::new("alexnet", ArrayConfig::new(0, 8))) {
        Err(ApiError::Config(ConfigError::ZeroHeight)) => {}
        other => panic!("expected typed config error, got {other:?}"),
    }
    match engine.eval(&EvalRequest::new("lenet-9000", ArrayConfig::new(8, 8))) {
        Err(ApiError::UnknownNetwork { name }) => assert_eq!(name, "lenet-9000"),
        other => panic!("expected unknown-network error, got {other:?}"),
    }
    // Batch overrides are bounded at the resolve choke point.
    let mut big = EvalRequest::new("alexnet", ArrayConfig::new(8, 8));
    big.batch = Some(1 << 30);
    assert!(matches!(engine.eval(&big), Err(ApiError::BadRequest(_))));

    let resps = serve_str(
        &engine,
        concat!(
            "{\"id\":1,\"type\":\"eval\",\"net\":\"alexnet\",\"config\":{\"height\":0,\"width\":8}}\n",
            "{\"id\":2,\"type\":\"eval\",\"net\":\"lenet-9000\"}\n",
            "this is not json\n",
            "{\"id\":4,\"type\":\"frobnicate\"}\n",
        ),
        &ServeOptions::default(),
    );
    assert_eq!(resps.len(), 4);
    let kind = |r: &Json| {
        r.get("error")
            .unwrap()
            .get("kind")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string()
    };
    for r in &resps {
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
    }
    assert_eq!(kind(&resps[0]), "invalid_config");
    assert_eq!(kind(&resps[1]), "unknown_network");
    assert_eq!(kind(&resps[2]), "bad_json");
    assert_eq!(kind(&resps[3]), "bad_request");
    // ids echo where recoverable.
    assert_eq!(resps[0].get("id").unwrap().as_usize(), Some(1));
    assert!(resps[2].get("id").is_none());
}

#[test]
fn serve_eval_response_equals_emulate_json() {
    // The acceptance contract: `echo <EvalRequest> | camuy serve` returns
    // the same document `camuy emulate --json` prints.
    let engine = Engine::new();
    let resps = serve_str(
        &engine,
        "{\"id\":1,\"type\":\"eval\",\"net\":\"alexnet\",\"config\":{\"height\":48,\"width\":24}}\n",
        &ServeOptions::default(),
    );
    assert_eq!(resps.len(), 1);
    assert_eq!(resps[0].get("ok").unwrap().as_bool(), Some(true));

    let coord = Coordinator::new(ArrayConfig::new(48, 24)).unwrap();
    let expected = coord
        .run_inference(&camuy::nets::build("alexnet").unwrap())
        .to_json();
    assert_eq!(*resps[0].get("result").unwrap(), expected);
}

#[test]
fn serve_preserves_order_and_register_is_a_barrier() {
    let engine = Engine::new();
    let mut input = String::new();
    // An eval of a name that only exists after the register must fail;
    // after the register barrier the same request succeeds.
    input.push_str("{\"id\":0,\"type\":\"eval\",\"net\":\"tinynet\"}\n");
    input.push_str(&format!(
        "{{\"id\":1,\"type\":\"register\",\"network\":{}}}\n",
        TINY_SPEC.replace('\n', " ")
    ));
    input.push_str("{\"id\":2,\"type\":\"eval\",\"net\":\"tinynet\"}\n");
    input.push_str("{\"id\":3,\"type\":\"zoo\"}\n");
    for i in 4..10 {
        input.push_str(&format!(
            "{{\"id\":{i},\"type\":\"eval\",\"net\":\"mobilenetv3l\",\
             \"config\":{{\"height\":{h},\"width\":16}}}}\n",
            h = 16 + 8 * (i % 3)
        ));
    }
    let resps = serve_str(
        &engine,
        &input,
        &ServeOptions {
            threads: 4,
            batch_max: 64,
            ..ServeOptions::default()
        },
    );
    assert_eq!(resps.len(), 10);
    for (i, r) in resps.iter().enumerate() {
        assert_eq!(r.get("id").unwrap().as_usize(), Some(i), "order broken");
    }
    assert_eq!(resps[0].get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(resps[1].get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(resps[2].get("ok").unwrap().as_bool(), Some(true));
    let nets = resps[3]
        .get("result")
        .unwrap()
        .get("networks")
        .unwrap()
        .as_arr()
        .unwrap();
    assert!(nets
        .iter()
        .any(|n| n.get("name").unwrap().as_str() == Some("tinynet")));
    for r in &resps[4..] {
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
    }
}

#[test]
fn serve_handles_sweep_memory_and_equal_pe() {
    let engine = Engine::new();
    let input = concat!(
        "{\"id\":\"s\",\"type\":\"sweep\",\"net\":\"alexnet\",\"grid\":\"smoke\",\"threads\":2}\n",
        "{\"id\":\"m\",\"type\":\"memory\",\"net\":\"vgg16\"}\n",
        "{\"id\":\"e\",\"type\":\"equal_pe\",\"budgets\":[4096],\"min_dim\":16,\"threads\":2}\n",
    );
    let resps = serve_str(
        &engine,
        input,
        &ServeOptions {
            threads: 2,
            batch_max: 8,
            ..ServeOptions::default()
        },
    );
    assert_eq!(resps.len(), 3);
    for r in &resps {
        assert_eq!(
            r.get("ok").unwrap().as_bool(),
            Some(true),
            "{}",
            r.to_string_compact()
        );
    }
    let sweep = resps[0].get("result").unwrap();
    assert_eq!(sweep.get("points").unwrap().as_arr().unwrap().len(), 16);
    assert!(sweep.get("best_energy").unwrap().get("height").is_some());
    let memory = resps[1].get("result").unwrap();
    assert!(memory.get("spilling_layers").unwrap().as_usize().unwrap() >= 1);
    let budgets = resps[2]
        .get("result")
        .unwrap()
        .get("budgets")
        .unwrap()
        .as_arr()
        .unwrap();
    assert_eq!(budgets.len(), 1);
    assert_eq!(budgets[0].get("pe_budget").unwrap().as_usize(), Some(4096));
}

#[test]
fn serve_tcp_answers_a_connection() {
    use std::io::{BufRead, BufReader, Write};

    let engine = Engine::new();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        threads: 2,
        batch_max: 8,
        max_connections: Some(1),
        ..ServeOptions::default()
    };
    std::thread::scope(|s| {
        s.spawn(|| camuy::api::serve_tcp(&engine, listener, &opts).unwrap());
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        stream
            .write_all(
                b"{\"id\":7,\"type\":\"eval\",\"net\":\"alexnet\",\
                  \"config\":{\"height\":16,\"width\":16}}\n",
            )
            .unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let mut line = String::new();
        BufReader::new(stream).read_line(&mut line).unwrap();
        let v = Json::parse(line.trim()).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_usize(), Some(7));
    });
}

#[test]
fn multi_array_and_per_layer_requests() {
    let engine = Engine::new();
    let mut req = EvalRequest::new("mobilenetv3l", ArrayConfig::new(32, 32));
    req.arrays = 4;
    match engine.eval(&req).unwrap() {
        EvalResponse::Multi {
            config, metrics, ..
        } => {
            assert_eq!(config.arrays, 4);
            assert!(metrics.makespan_cycles > 0);
        }
        other => panic!("expected multi response, got {other:?}"),
    }

    let mut req = EvalRequest::new("alexnet", ArrayConfig::new(32, 32));
    req.per_layer = true;
    match engine.eval(&req).unwrap() {
        EvalResponse::Single { run, per_layer, .. } => {
            let pl = per_layer.expect("per-layer report");
            assert_eq!(pl.rooflines.len(), run.timeline.len());
            assert!(pl.machine_balance > 0.0);
        }
        other => panic!("expected single response, got {other:?}"),
    }
    // The roofline report reaches the wire format too.
    let json = engine.eval(&req).unwrap().to_json();
    let roofline = json.get("roofline").expect("roofline in JSON");
    assert!(!roofline.get("layers").unwrap().as_arr().unwrap().is_empty());
}

#[test]
fn network_spec_export_roundtrips_through_registration() {
    // Dump a zoo model, rename it, re-register it: a first-class workflow.
    let engine = Engine::new();
    let spec = engine.network_spec("alexnet").unwrap();
    let renamed = match spec {
        Json::Obj(mut m) => {
            m.insert("name".to_string(), Json::str("my-alexnet"));
            Json::Obj(m)
        }
        _ => panic!("spec must be an object"),
    };
    let reg = engine.register_network_json(&renamed).unwrap();
    assert_eq!(reg.name, "my-alexnet");
    let cfg = ArrayConfig::new(64, 32);
    let mine = engine
        .eval(&EvalRequest::new("my-alexnet", cfg.clone()))
        .unwrap();
    let zoo = engine.eval(&EvalRequest::new("alexnet", cfg)).unwrap();
    assert_eq!(mine.total(), zoo.total());
}

/// TINY_SPEC's conv stack rewired as a residual block: c1 feeds both c2
/// and an add junction that c2's output also reaches.
const TINY_GRAPH_SPEC: &str = r#"{
  "name": "tinyskip",
  "layers": [
    {"op": "conv2d", "name": "c1", "input": {"h": 16, "w": 16},
     "c_in": 3, "c_out": 8, "kernel": 3, "stride": 1, "padding": 1},
    {"op": "conv2d", "name": "c2", "input": {"h": 16, "w": 16},
     "c_in": 8, "c_out": 8, "kernel": 3, "padding": 1},
    {"op": "linear", "name": "fc", "in_features": 2048, "out_features": 10}
  ],
  "junctions": [{"name": "res", "op": "add"}],
  "edges": [["c1", "c2"], ["c1", "res"], ["c2", "res"], ["res", "fc"]]
}"#;

#[test]
fn graph_requests_cover_zoo_and_registered_dags() {
    use camuy::api::GraphRequest;

    let engine = Engine::new();
    let cfg = ArrayConfig::new(64, 64);

    // Zoo DAG: the graph metrics equal the flat eval byte for byte.
    let resp = engine
        .graph(&GraphRequest::new("resnet50", cfg.clone()))
        .unwrap();
    assert!(!resp.is_chain);
    assert_eq!(resp.junctions, 16);
    let flat = engine
        .eval(&EvalRequest::new("resnet50", cfg.clone()))
        .unwrap();
    assert_eq!(&resp.metrics, flat.total());
    assert!(resp.liveness.peak_bytes > resp.liveness.chain_peak_bytes);
    assert_eq!(resp.schedule.makespan_cycles, resp.schedule.serialized_cycles);

    // Branch parallelism: four arrays beat one on a DAG, and the bank's
    // makespan never exceeds the serialized baseline.
    let mut par = GraphRequest::new("resnet50", cfg.clone());
    par.arrays = 4;
    let par = engine.graph(&par).unwrap();
    assert!(par.schedule.makespan_cycles <= par.schedule.serialized_cycles);
    assert!(par.schedule.makespan_cycles >= par.schedule.critical_path_cycles);

    // A registered graph spec resolves in DAG form…
    engine.register_network_str(TINY_GRAPH_SPEC).unwrap();
    let tiny = engine
        .graph(&GraphRequest::new("tinyskip", cfg.clone()))
        .unwrap();
    assert!(!tiny.is_chain);
    assert_eq!(tiny.junctions, 1);
    assert_eq!(tiny.layers, 3);
    // …and its chain lowering serves plain eval requests.
    assert!(engine.eval(&EvalRequest::new("tinyskip", cfg.clone())).is_ok());

    // Unknown networks surface the typed error.
    match engine.graph(&GraphRequest::new("lenet-9000", cfg)) {
        Err(ApiError::UnknownNetwork { name }) => assert_eq!(name, "lenet-9000"),
        other => panic!("expected UnknownNetwork, got {other:?}"),
    }
}

#[test]
fn serve_answers_graph_requests() {
    let engine = Engine::new();
    let input = concat!(
        "{\"id\":1,\"type\":\"graph\",\"net\":\"googlenet\",\"arrays\":4,",
        "\"config\":{\"height\":32,\"width\":32}}\n",
        "{\"id\":2,\"type\":\"memory\",\"net\":\"resnet50\",\"graph\":true}\n",
        "{\"id\":3,\"type\":\"graph\",\"net\":\"lenet-9000\"}\n",
    );
    let resps = serve_str(&engine, input, &ServeOptions::default());
    assert_eq!(resps.len(), 3);

    assert_eq!(resps[0].get("ok").unwrap().as_bool(), Some(true));
    let g = resps[0].get("result").unwrap();
    assert_eq!(g.get("junctions").unwrap().as_usize(), Some(9));
    assert_eq!(g.get("is_chain").unwrap().as_bool(), Some(false));
    let sched = g.get("schedule").unwrap();
    let makespan = sched.get("makespan_cycles").unwrap().as_f64().unwrap();
    let serial = sched.get("serialized_cycles").unwrap().as_f64().unwrap();
    assert!(makespan < serial, "branches should overlap on 4 arrays");
    let live = g.get("liveness").unwrap();
    assert!(live.get("peak_residency_bytes").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(live.get("top_steps").unwrap().as_arr().unwrap().len(), 10);

    assert_eq!(resps[1].get("ok").unwrap().as_bool(), Some(true));
    let mem = resps[1].get("result").unwrap();
    let mlive = mem.get("liveness").expect("liveness attached when graph:true");
    let peak = mlive.get("peak_residency_bytes").unwrap().as_f64().unwrap();
    let chain = mlive.get("chain_peak_bytes").unwrap().as_f64().unwrap();
    assert!(peak > chain, "resnet50 holds skip tensors live");

    assert_eq!(resps[2].get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(
        resps[2]
            .get("error")
            .unwrap()
            .get("kind")
            .unwrap()
            .as_str(),
        Some("unknown_network")
    );
}

#[test]
fn telemetry_counters_are_monotone_across_replayed_batches() {
    camuy::telemetry::set_enabled(true);
    let engine = Engine::new();
    let input = concat!(
        "{\"id\":1,\"type\":\"eval\",\"net\":\"alexnet\",",
        "\"config\":{\"height\":16,\"width\":16}}\n",
        "{\"id\":2,\"type\":\"eval\",\"net\":\"alexnet\",",
        "\"config\":{\"height\":24,\"width\":16}}\n",
        "{\"id\":3,\"type\":\"memory\",\"net\":\"alexnet\"}\n",
    );
    let evals = |s: &TelemetrySnapshot| s.request(ReqKind::Eval).count;
    let mems = |s: &TelemetrySnapshot| s.request(ReqKind::Memory).count;
    let stats = |s: &TelemetrySnapshot| s.request(ReqKind::Stats).count;

    let before = engine.stats(&StatsRequest::default()).snapshot;
    let first = serve_str(&engine, input, &ServeOptions::default());
    for r in &first {
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
    }
    let mid = engine.stats(&StatsRequest::default()).snapshot;
    serve_str(&engine, input, &ServeOptions::default());
    let after = engine.stats(&StatsRequest::default()).snapshot;

    // The registry is process-global and the harness runs tests
    // concurrently, so every assertion is a monotone delta (>=) over
    // this test's own traffic, never an exact total.
    assert!(evals(&mid) >= evals(&before) + 2);
    assert!(evals(&after) >= evals(&mid) + 2);
    assert!(mems(&after) >= mems(&before) + 2);
    assert!(stats(&after) >= stats(&before) + 2);
    assert!(after.batches >= before.batches + 2);
    assert!(after.bytes_in > before.bytes_in);
    assert!(after.bytes_out > before.bytes_out);
    assert!(after.total_requests() >= before.total_requests() + 8);

    // The second replay answers from this engine's memo table, and the
    // attached per-shard stats stay consistent with the aggregate.
    let ec = after.eval_cache.expect("eval-cache stats attached");
    assert!(ec.hits >= 2);
    assert_eq!(ec.entries, engine.cache().len());
    let shard_entries: usize = ec.shards.iter().map(|s| s.entries).sum();
    assert_eq!(shard_entries, ec.entries);
    assert!(after.networks.is_some());
}

#[test]
fn telemetry_quantiles_bracket_observed_latencies() {
    camuy::telemetry::set_enabled(true);
    let engine = Engine::new();
    for h in [16usize, 24, 32, 40, 48, 56, 64, 72] {
        let req = EvalRequest::new("alexnet", ArrayConfig::new(h, 16));
        engine.eval(&req).unwrap();
        engine.eval(&req).unwrap();
    }
    let snap = engine.stats(&StatsRequest::default()).snapshot;
    let lat = &snap.request(ReqKind::Eval).latency;
    assert!(lat.count >= 16);
    assert!(lat.max > 0, "evals take nonzero time");

    // Quantiles are exact bucket bounds clamped to the recorded range,
    // so they are ordered and bracketed by [min, max].
    let p50 = lat.quantile(0.50);
    let p95 = lat.quantile(0.95);
    let p99 = lat.quantile(0.99);
    assert!(lat.min <= p50);
    assert!(p50 <= p95 && p95 <= p99);
    assert!(p99 <= lat.max);
    let mean = lat.mean();
    assert!((lat.min as f64..=lat.max as f64).contains(&mean));

    // The merged all-kinds histogram contains at least these samples.
    let merged = snap.request_latency();
    assert!(merged.count >= lat.count);
    assert!(merged.max >= lat.max && merged.min <= lat.min);
}

#[test]
fn serve_tcp_answers_a_stats_request() {
    use std::io::{BufRead, BufReader, Write};

    camuy::telemetry::set_enabled(true);
    let engine = Engine::new();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        threads: 2,
        batch_max: 8,
        max_connections: Some(1),
        ..ServeOptions::default()
    };
    std::thread::scope(|s| {
        s.spawn(|| camuy::api::serve_tcp(&engine, listener, &opts).unwrap());
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());

        // Drive one eval first so the stats that follow have traffic.
        stream
            .write_all(
                b"{\"id\":8,\"type\":\"eval\",\"net\":\"alexnet\",\
                  \"config\":{\"height\":16,\"width\":16}}\n",
            )
            .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let eval = Json::parse(line.trim()).unwrap();
        assert_eq!(eval.get("ok").unwrap().as_bool(), Some(true));

        stream.write_all(b"{\"id\":9,\"type\":\"stats\"}\n").unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(line.trim()).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_usize(), Some(9));

        let r = v.get("result").unwrap();
        assert_eq!(r.get("enabled").unwrap().as_bool(), Some(true));
        let eval = r.get("requests").unwrap().get("eval").unwrap();
        assert!(eval.get("count").unwrap().as_f64().unwrap() >= 1.0);
        assert!(eval.get("latency").unwrap().get("p99").is_some());
        assert!(r.get("request_latency").unwrap().get("p50").is_some());
        let cache = r.get("eval_cache").unwrap();
        assert!(cache.get("hit_rate").is_some());
        assert!(!cache.get("shards").unwrap().as_arr().unwrap().is_empty());
        assert!(r.get("plan_cache").unwrap().get("entries").is_some());
        assert!(r.get("pool").unwrap().get("queue_depth").is_some());
        let sv = r.get("serve").unwrap();
        assert!(sv.get("bytes_in").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("networks").is_some());
    });
}
