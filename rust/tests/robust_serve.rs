//! Operational-hardening coverage for the serve tier (DESIGN.md §15):
//! per-request deadlines, panic isolation, admission control, oversized
//! line resynchronization, warm snapshot/restore, and the fault-injection
//! harness driving all of it.
//!
//! The faultpoint table and the telemetry registry are process-global, so
//! every test here serializes on [`HARNESS`] — within this test binary the
//! counter deltas below are exact.

use camuy::api::{Engine, ServeOptions};
use camuy::faultpoint::{self, Action};
use camuy::util::json::Json;
use std::sync::Mutex;
use std::time::Duration;

static HARNESS: Mutex<()> = Mutex::new(());

fn harness() -> std::sync::MutexGuard<'static, ()> {
    let guard = HARNESS.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::disarm_all();
    guard
}

/// Run the serve loop over a request string, returning parsed responses.
fn serve_str(engine: &Engine, input: &str, opts: &ServeOptions) -> Vec<Json> {
    let mut out: Vec<u8> = Vec::new();
    camuy::api::serve(engine, input.as_bytes(), &mut out, opts).unwrap();
    String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .collect()
}

fn error_kind(resp: &Json) -> &str {
    resp.get("error").unwrap().get("kind").unwrap().as_str().unwrap()
}

fn error_message(resp: &Json) -> &str {
    resp.get("error").unwrap().get("message").unwrap().as_str().unwrap()
}

fn is_ok(resp: &Json) -> bool {
    resp.get("ok").unwrap().as_bool() == Some(true)
}

const EVAL_LINE: &str =
    "{\"id\":9,\"type\":\"eval\",\"net\":\"alexnet\",\"config\":{\"height\":24,\"width\":16}}\n";

/// A 16-point-per-axis sweep (256 cells, several dispatch units) pinned to
/// one thread so checkpoint order is deterministic.
const SLOW_SWEEP_LINE: &str = "{\"id\":1,\"type\":\"sweep\",\"net\":\"alexnet\",\
     \"grid\":{\"lo\":8,\"hi\":128,\"step\":8},\"threads\":1,\"deadline_ms\":100}\n";

#[test]
fn deadline_exceeded_sweep_reports_progress_and_next_request_is_clean() {
    let _g = harness();
    let tel = camuy::telemetry::global();
    let deadlines_before = tel.deadline_exceeded.get();

    // Each sweep dispatch unit sleeps 40 ms, so the 100 ms budget fires a
    // few units in — hardware speed is irrelevant.
    faultpoint::arm("sweep.unit", Action::Delay(Duration::from_millis(40)), 1000);
    let engine = Engine::new();
    let started = std::time::Instant::now();
    let resps = serve_str(&engine, SLOW_SWEEP_LINE, &ServeOptions::default());
    let elapsed = started.elapsed();
    faultpoint::disarm_all();

    assert_eq!(resps.len(), 1);
    assert!(!is_ok(&resps[0]), "{}", resps[0].to_string_compact());
    assert_eq!(error_kind(&resps[0]), "deadline_exceeded");
    let err = resps[0].get("error").unwrap();
    assert_eq!(err.get("deadline_ms").unwrap().as_usize(), Some(100));
    assert!(err.get("progress").unwrap().as_usize().unwrap() >= 1);
    assert!(
        elapsed < Duration::from_secs(3),
        "cancellation took {elapsed:?} against a 100 ms budget"
    );
    assert!(tel.deadline_exceeded.get() > deadlines_before);

    // The engine that just cancelled mid-sweep answers the next request
    // byte-identically to a fresh engine: no poisoned caches, no leaked
    // token, no half-written state.
    let after = serve_str(&engine, EVAL_LINE, &ServeOptions::default());
    let fresh = serve_str(&Engine::new(), EVAL_LINE, &ServeOptions::default());
    assert_eq!(
        after[0].to_string_compact(),
        fresh[0].to_string_compact(),
        "post-cancellation response diverged from a fresh engine"
    );
}

#[test]
fn injected_panics_answer_internal_and_the_server_keeps_answering() {
    let _g = harness();
    let tel = camuy::telemetry::global();
    let panics_before = tel.panics_caught.get();
    let engine = Engine::new();

    // One injected panic per request kind; the engine and its caches must
    // survive every one of them.
    let kinds: [(&str, &str); 3] = [
        (
            "graph.schedule",
            "{\"id\":1,\"type\":\"graph\",\"net\":\"resnet50\",\
             \"config\":{\"height\":32,\"width\":32}}\n",
        ),
        (
            "sweep.unit",
            "{\"id\":1,\"type\":\"sweep\",\"net\":\"alexnet\",\"grid\":\"smoke\",\
             \"threads\":1}\n",
        ),
        // A deadline-carrying eval rides the per-request guard directly.
        (
            "eval.inner",
            "{\"id\":1,\"type\":\"eval\",\"net\":\"alexnet\",\"deadline_ms\":60000,\
             \"config\":{\"height\":16,\"width\":16}}\n",
        ),
    ];
    for (site, line) in kinds {
        faultpoint::arm(site, Action::Panic, 1);
        let broken = serve_str(&engine, line, &ServeOptions::default());
        assert_eq!(broken.len(), 1, "{site}");
        assert!(!is_ok(&broken[0]), "{site}: injected panic must fail the request");
        assert_eq!(error_kind(&broken[0]), "internal", "{site}");
        assert!(
            error_message(&broken[0]).contains("injected panic"),
            "{site}: panic payload must reach the message"
        );
        // The budget is spent; the identical request now succeeds on the
        // same engine over the same connection machinery.
        let healed = serve_str(&engine, line, &ServeOptions::default());
        assert!(is_ok(&healed[0]), "{site}: {}", healed[0].to_string_compact());
    }
    assert_eq!(tel.panics_caught.get(), panics_before + 3);

    // Caches survived the unwinds: repeat evals are memo-table hits.
    let hits_before = engine.cache().hits();
    let again = serve_str(&engine, EVAL_LINE, &ServeOptions::default());
    assert!(is_ok(&again[0]));
    serve_str(&engine, EVAL_LINE, &ServeOptions::default());
    assert!(engine.cache().hits() > hits_before);
}

#[test]
fn batched_eval_panic_falls_back_to_guarded_retry() {
    let _g = harness();
    let engine = Engine::new();
    // Deadline-free evals ride the batched seeding path; an injected
    // panic there is caught at the batch level and every eval is retried
    // through the per-request guard — the fire budget is spent, so all
    // answers come back ok and nothing is lost.
    faultpoint::arm("eval.inner", Action::Panic, 1);
    let input = concat!(
        "{\"id\":1,\"type\":\"eval\",\"net\":\"alexnet\",\
         \"config\":{\"height\":16,\"width\":16}}\n",
        "{\"id\":2,\"type\":\"eval\",\"net\":\"alexnet\",\
         \"config\":{\"height\":32,\"width\":16}}\n",
    );
    let resps = serve_str(&engine, input, &ServeOptions::default());
    assert_eq!(faultpoint::fired("eval.inner"), 1);
    faultpoint::disarm_all();
    assert_eq!(resps.len(), 2);
    for r in &resps {
        assert!(is_ok(r), "{}", r.to_string_compact());
    }
}

#[test]
fn concurrent_clients_survive_injected_panics_without_losing_telemetry() {
    let _g = harness();
    const FIRES: usize = 4;
    let tel = camuy::telemetry::global();
    let panics_before = tel.panics_caught.get();
    faultpoint::arm("eval.inner", Action::Panic, FIRES);
    let engine = Engine::new();
    std::thread::scope(|s| {
        for c in 0..3usize {
            let engine = &engine;
            s.spawn(move || {
                for i in 0..6usize {
                    let line = format!(
                        "{{\"id\":{i},\"type\":\"eval\",\"net\":\"alexnet\",\
                         \"config\":{{\"height\":{h},\"width\":16}}}}\n",
                        h = 16 + 8 * c + 8 * i
                    );
                    let resps = serve_str(engine, &line, &ServeOptions::default());
                    // Every request gets exactly one answer. A panic on
                    // the batched path is retried through the guard; the
                    // retry may consume another fire and answer
                    // `internal` — but nothing hangs and nothing is lost.
                    assert_eq!(resps.len(), 1);
                    assert!(
                        is_ok(&resps[0]) || error_kind(&resps[0]) == "internal",
                        "{}",
                        resps[0].to_string_compact()
                    );
                }
            });
        }
    });
    // Every armed fire is accounted for, every panic was isolated, and
    // the engine keeps answering after the storm.
    assert_eq!(faultpoint::fired("eval.inner"), FIRES, "fires were lost");
    assert!(tel.panics_caught.get() >= panics_before + 1);
    faultpoint::disarm_all();
    let after = serve_str(&engine, EVAL_LINE, &ServeOptions::default());
    assert!(is_ok(&after[0]), "{}", after[0].to_string_compact());
}

#[test]
fn admission_control_sheds_overflow_and_exempts_the_control_plane() {
    let _g = harness();
    let tel = camuy::telemetry::global();
    let shed_before = tel.requests_shed.get();
    // The first request holds the only admission slot for ~300 ms (one
    // smoke-sweep unit, delayed), so the later compute requests land in a
    // batch together and at least one is shed.
    faultpoint::arm("sweep.unit", Action::Delay(Duration::from_millis(300)), 1);
    let engine = Engine::new();
    let input = concat!(
        "{\"id\":1,\"type\":\"sweep\",\"net\":\"alexnet\",\"grid\":\"smoke\",\"threads\":1}\n",
        "{\"id\":2,\"type\":\"sweep\",\"net\":\"alexnet\",\"grid\":\"smoke\",\"threads\":1}\n",
        "{\"id\":3,\"type\":\"sweep\",\"net\":\"alexnet\",\"grid\":\"smoke\",\"threads\":1}\n",
        "{\"id\":4,\"type\":\"stats\"}\n",
    );
    let resps = serve_str(
        &engine,
        input,
        &ServeOptions {
            admission_max: 1,
            threads: 2,
            ..ServeOptions::default()
        },
    );
    faultpoint::disarm_all();
    assert_eq!(resps.len(), 4);
    let shed: Vec<&Json> = resps.iter().filter(|r| !is_ok(r)).collect();
    assert!(!shed.is_empty(), "no request was shed at admission_max=1");
    for r in &shed {
        assert_eq!(error_kind(r), "overloaded", "{}", r.to_string_compact());
        let hint = r.get("error").unwrap().get("retry_after_ms").unwrap();
        assert!(hint.as_usize().unwrap() >= 10);
    }
    // Stats is control plane: answered even under shedding.
    let stats = resps.iter().find(|r| r.get("id").and_then(Json::as_usize) == Some(4));
    assert!(is_ok(stats.unwrap()), "stats must bypass admission");
    assert!(tel.requests_shed.get() > shed_before);
}

const CHAIN_SPEC: &str = r#"{
  "name": "hardnet",
  "layers": [
    {"op": "conv2d", "name": "c1", "input": {"h": 16, "w": 16},
     "c_in": 3, "c_out": 8, "kernel": 3, "stride": 1, "padding": 1},
    {"op": "linear", "name": "fc", "in_features": 2048, "out_features": 10}
  ]
}"#;

const GRAPH_SPEC: &str = r#"{
  "name": "hardskip",
  "layers": [
    {"op": "conv2d", "name": "c1", "input": {"h": 16, "w": 16},
     "c_in": 3, "c_out": 8, "kernel": 3, "stride": 1, "padding": 1},
    {"op": "conv2d", "name": "c2", "input": {"h": 16, "w": 16},
     "c_in": 8, "c_out": 8, "kernel": 3, "padding": 1},
    {"op": "linear", "name": "fc", "in_features": 2048, "out_features": 10}
  ],
  "junctions": [{"name": "res", "op": "add"}],
  "edges": [["c1", "c2"], ["c1", "res"], ["c2", "res"], ["res", "fc"]]
}"#;

#[test]
fn snapshot_restore_round_trips_chains_and_dags_byte_identically() {
    let _g = harness();
    let tel = camuy::telemetry::global();
    let writes_before = tel.snapshot_writes.get();

    let engine = Engine::new();
    engine.register_network_str(CHAIN_SPEC).unwrap();
    engine.register_network_str(GRAPH_SPEC).unwrap();

    let dir = std::env::temp_dir();
    let path = dir.join(format!("camuy-robust-snap-{}.json", std::process::id()));
    engine.snapshot_to(&path).unwrap();
    assert!(tel.snapshot_writes.get() > writes_before);

    let doc = engine.snapshot_json();
    assert_eq!(doc.get("version").unwrap().as_usize(), Some(camuy::api::SNAPSHOT_VERSION));
    assert_eq!(doc.get("kind").unwrap().as_str(), Some("camuy-registry"));
    assert_eq!(doc.get("networks").unwrap().as_arr().unwrap().len(), 2);

    let restored = Engine::new();
    assert_eq!(restored.restore_from(&path).unwrap(), 2);
    std::fs::remove_file(&path).ok();

    // Both forms answer byte-identically on the restored engine: the
    // chain through eval, the DAG through a graph request (junctions and
    // edges must have survived the round trip).
    for line in [
        "{\"id\":1,\"type\":\"eval\",\"net\":\"hardnet\",\
         \"config\":{\"height\":16,\"width\":16}}\n",
        "{\"id\":2,\"type\":\"graph\",\"net\":\"hardskip\",\
         \"config\":{\"height\":16,\"width\":16}}\n",
    ] {
        let a = serve_str(&engine, line, &ServeOptions::default());
        let b = serve_str(&restored, line, &ServeOptions::default());
        assert!(is_ok(&a[0]), "{}", a[0].to_string_compact());
        assert_eq!(a[0].to_string_compact(), b[0].to_string_compact());
    }

    // Version discipline: a snapshot from the future is refused loudly.
    let tampered = match doc {
        Json::Obj(mut m) => {
            m.insert("version".to_string(), Json::num(99.0));
            Json::Obj(m)
        }
        _ => unreachable!("snapshot is an object"),
    };
    let fresh = Engine::new();
    let err = fresh.restore_json(&tampered).unwrap_err();
    assert_eq!(err.kind(), "bad_request");
    assert!(err.to_string().contains("version"));
    // And a structurally empty document is refused, not half-restored.
    let empty = Json::obj(vec![("version", Json::num(1.0))]);
    assert!(fresh.restore_json(&empty).is_err());
}

#[test]
fn oversized_lines_resynchronize_instead_of_killing_the_connection() {
    let _g = harness();
    let engine = Engine::new();
    // 5 MiB of garbage (over the 4 MiB line cap), then a valid request:
    // the garbage answers a structured error and the stream recovers.
    let mut input = "x".repeat(5 << 20);
    input.push('\n');
    input.push_str(EVAL_LINE);
    let resps = serve_str(&engine, &input, &ServeOptions::default());
    assert_eq!(resps.len(), 2, "stream did not resynchronize");
    assert!(!is_ok(&resps[0]));
    assert_eq!(error_kind(&resps[0]), "bad_request");
    assert!(error_message(&resps[0]).contains("exceeds"));
    assert!(is_ok(&resps[1]), "{}", resps[1].to_string_compact());

    // An oversized line truncated by EOF (no newline to resynchronize to)
    // still answers and terminates cleanly.
    let truncated = "y".repeat(5 << 20);
    let resps = serve_str(&engine, &truncated, &ServeOptions::default());
    assert_eq!(resps.len(), 1);
    assert_eq!(error_kind(&resps[0]), "bad_request");
}

#[test]
fn tcp_connection_cap_refuses_with_a_structured_overloaded_line() {
    use std::io::{BufRead, BufReader, Write};

    let _g = harness();
    let tel = camuy::telemetry::global();
    let shed_before = tel.requests_shed.get();

    let engine = Engine::new();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        threads: 2,
        batch_max: 8,
        max_connections: Some(2),
        max_concurrent: 1,
        ..ServeOptions::default()
    };
    std::thread::scope(|s| {
        s.spawn(|| camuy::api::serve_tcp(&engine, listener, &opts).unwrap());

        // Connection 1 occupies the only slot.
        let mut c1 = std::net::TcpStream::connect(addr).unwrap();
        let mut r1 = BufReader::new(c1.try_clone().unwrap());
        c1.write_all(EVAL_LINE.as_bytes()).unwrap();
        let mut line = String::new();
        r1.read_line(&mut line).unwrap();
        assert!(is_ok(&Json::parse(line.trim()).unwrap()));

        // Connection 2 is over the cap: it gets one structured refusal
        // line, then EOF — not a silent close.
        let c2 = std::net::TcpStream::connect(addr).unwrap();
        let mut r2 = BufReader::new(c2);
        line.clear();
        r2.read_line(&mut line).unwrap();
        let refusal = Json::parse(line.trim()).unwrap();
        assert!(!is_ok(&refusal), "{}", refusal.to_string_compact());
        assert_eq!(error_kind(&refusal), "overloaded");
        let hint = refusal.get("error").unwrap().get("retry_after_ms").unwrap();
        assert!(hint.as_usize().unwrap() >= 10);
        line.clear();
        assert_eq!(r2.read_line(&mut line).unwrap(), 0, "refusal must close");

        // Free the slot; a fresh connection is admitted (retry briefly —
        // the slot is released a hair after the client sees EOF).
        c1.shutdown(std::net::Shutdown::Write).unwrap();
        line.clear();
        while r1.read_line(&mut line).unwrap() > 0 {
            line.clear();
        }
        for attempt in 0.. {
            let mut c3 = std::net::TcpStream::connect(addr).unwrap();
            let mut r3 = BufReader::new(c3.try_clone().unwrap());
            c3.write_all(EVAL_LINE.as_bytes()).unwrap();
            c3.shutdown(std::net::Shutdown::Write).unwrap();
            line.clear();
            r3.read_line(&mut line).unwrap();
            let resp = Json::parse(line.trim()).unwrap();
            if is_ok(&resp) {
                break;
            }
            assert_eq!(error_kind(&resp), "overloaded");
            assert!(attempt < 50, "slot never freed");
            std::thread::sleep(Duration::from_millis(50));
        }
    });
    assert!(tel.requests_shed.get() > shed_before);
}

#[test]
fn periodic_and_drain_snapshots_restore_a_warm_server() {
    use std::io::{BufRead, BufReader, Write};

    let _g = harness();
    let dir = std::env::temp_dir();
    let path = dir.join(format!("camuy-robust-warm-{}.json", std::process::id()));
    std::fs::remove_file(&path).ok();

    let engine = Engine::new();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        threads: 2,
        batch_max: 8,
        max_connections: Some(2),
        snapshot: Some(path.clone()),
        snapshot_secs: 1,
        ..ServeOptions::default()
    };
    std::thread::scope(|s| {
        s.spawn(|| camuy::api::serve_tcp(&engine, listener, &opts).unwrap());

        // Register over connection 1, then let the accept loop idle past
        // the periodic-snapshot interval.
        let mut c1 = std::net::TcpStream::connect(addr).unwrap();
        let mut r1 = BufReader::new(c1.try_clone().unwrap());
        let register = format!(
            "{{\"id\":1,\"type\":\"register\",\"network\":{}}}\n",
            CHAIN_SPEC.replace('\n', " ")
        );
        c1.write_all(register.as_bytes()).unwrap();
        let mut line = String::new();
        r1.read_line(&mut line).unwrap();
        assert!(is_ok(&Json::parse(line.trim()).unwrap()));
        drop(r1);
        drop(c1);
        std::thread::sleep(Duration::from_millis(1600));
        assert!(path.exists(), "periodic snapshot was never written");

        // A second connection lets the server reach its connection cap
        // and drain, writing the final snapshot on the way out.
        let mut c2 = std::net::TcpStream::connect(addr).unwrap();
        let mut r2 = BufReader::new(c2.try_clone().unwrap());
        c2.write_all(b"{\"id\":2,\"type\":\"zoo\"}\n").unwrap();
        c2.shutdown(std::net::Shutdown::Write).unwrap();
        line.clear();
        r2.read_line(&mut line).unwrap();
        assert!(is_ok(&Json::parse(line.trim()).unwrap()));
    });

    // A cold binary restores the snapshot and answers for the registered
    // network byte-identically to the original server's engine.
    let restored = Engine::new();
    assert_eq!(restored.restore_from(&path).unwrap(), 1);
    std::fs::remove_file(&path).ok();
    let line = "{\"id\":3,\"type\":\"eval\",\"net\":\"hardnet\",\
                \"config\":{\"height\":16,\"width\":16}}\n";
    let warm = serve_str(&engine, line, &ServeOptions::default());
    let cold = serve_str(&restored, line, &ServeOptions::default());
    assert!(is_ok(&warm[0]), "{}", warm[0].to_string_compact());
    assert_eq!(warm[0].to_string_compact(), cold[0].to_string_compact());
}

#[test]
fn stats_surface_exposes_the_robust_counters() {
    let _g = harness();
    let engine = Engine::new();
    let resps = serve_str(&engine, "{\"id\":1,\"type\":\"stats\"}\n", &ServeOptions::default());
    assert!(is_ok(&resps[0]));
    let robust = resps[0].get("result").unwrap().get("robust").unwrap();
    for key in [
        "requests_shed",
        "deadline_exceeded",
        "panics_caught",
        "snapshot_writes",
        "admission_depth",
    ] {
        assert!(robust.get(key).is_some(), "missing robust.{key}");
    }
}
