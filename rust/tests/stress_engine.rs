//! Concurrency stress: one long-lived [`camuy::api::Engine`] hammered
//! from many client threads with a mixed eval / sweep / register / graph
//! workload — exactly the shape `camuy serve --listen` produces, where
//! every TCP connection fans requests onto one shared engine, one shared
//! sharded memo table, one shared plan cache, and one shared persistent
//! thread pool (DESIGN.md §11).
//!
//! The invariant: every response produced under contention must be
//! **byte-identical** (as compact wire JSON) to the same request sequence
//! run serially on a fresh engine. Responses are deterministic functions
//! of (request, per-thread registration prefix), so any divergence means
//! shared state leaked between requests — a torn cache entry, a stale
//! plan, a cross-thread registration race.

use camuy::api::{sweep_json, Engine, EvalRequest, SweepRequest, SweepSpec};
use camuy::config::{ArrayConfig, Dataflow};

/// A tiny registerable network, unique per client thread.
fn spec_for(thread: usize) -> String {
    format!(
        r#"{{
  "name": "stress-t{thread}",
  "layers": [
    {{"op": "conv2d", "name": "c1", "input": {{"h": 14, "w": 14}},
     "c_in": {cin}, "c_out": 16, "kernel": 3, "stride": 1, "padding": 1}},
    {{"op": "linear", "name": "fc", "in_features": {feat}, "out_features": 10}}
  ]
}}"#,
        cin = 3 + thread,
        feat = 16 * 14 * 14,
    )
}

/// The deterministic request script of one client thread, applied to
/// `engine`; returns the compact-JSON transcript of every response.
fn run_script(engine: &Engine, thread: usize) -> Vec<String> {
    let mut out = Vec::new();
    // Register this thread's own network first; later evals resolve it.
    let reg = engine
        .register_network_json(&camuy::util::json::Json::parse(&spec_for(thread)).unwrap())
        .expect("register");
    out.push(format!("registered {} replaced {}", reg.name, reg.replaced));
    for i in 0..12 {
        // Mixed geometries, both dataflows, overlapping across threads so
        // the sharded memo table sees concurrent hits and misses on the
        // same keys.
        let h = 8 + 8 * ((thread + i) % 4);
        let w = 8 + 8 * (i % 4);
        let mut cfg = ArrayConfig::new(h, w);
        if i % 3 == 0 {
            cfg = cfg.with_dataflow(Dataflow::OutputStationary);
        }
        let net = if i % 4 == 0 {
            format!("stress-t{thread}")
        } else {
            "alexnet".to_string()
        };
        let resp = engine.eval(&EvalRequest::new(net, cfg)).expect("eval");
        out.push(resp.to_json().to_string_compact());
        if i % 5 == 0 {
            // A sweep (plan-cache traffic) with a small grid; threads = 2
            // nests pool jobs inside pool jobs.
            let mut spec = SweepSpec::smoke();
            spec.threads = 2;
            let sweep = engine
                .sweep(&SweepRequest {
                    net: "alexnet".to_string(),
                    spec,
                })
                .expect("sweep");
            out.push(sweep_json(&sweep).to_string_compact());
        }
    }
    out
}

#[test]
fn concurrent_clients_match_serial_replay_byte_for_byte() {
    let n_threads = 8;
    // Contended run: all client scripts at once against one engine.
    let shared = Engine::new();
    let mut concurrent: Vec<Vec<String>> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_threads)
            .map(|t| {
                let shared = &shared;
                s.spawn(move || run_script(shared, t))
            })
            .collect();
        for h in handles {
            concurrent.push(h.join().expect("client thread"));
        }
    });
    // Serial replay: the same scripts, one after another, fresh engine.
    let serial_engine = Engine::new();
    for (t, got) in concurrent.iter().enumerate() {
        let want = run_script(&serial_engine, t);
        assert_eq!(
            got.len(),
            want.len(),
            "thread {t}: transcript length diverged"
        );
        for (j, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "thread {t} response {j} diverged under contention");
        }
    }
    // The shared engine really did share state: the memo table saw the
    // overlapping keys, and at least one sweep plan is resident.
    assert!(!shared.cache().is_empty());
    assert!(shared.plans().len() >= 1);
    assert!(shared.plans().hits() > 0, "replayed sweeps must hit the plan cache");
}

#[test]
fn concurrent_eval_batches_match_individual_evals() {
    // eval_batch seeds the shared cache through the segmented cores (both
    // dataflows); racing batches must still answer exactly like
    // Engine::eval.
    let engine = Engine::new();
    let reqs: Vec<EvalRequest> = (0..24)
        .map(|i| {
            let cfg = ArrayConfig::new(8 + 8 * (i % 3), 8 + 4 * (i % 5));
            let cfg = if i % 2 == 0 {
                cfg.with_dataflow(Dataflow::OutputStationary)
            } else {
                cfg
            };
            EvalRequest::new("alexnet", cfg)
        })
        .collect();
    let fresh = Engine::new();
    let want: Vec<String> = reqs
        .iter()
        .map(|r| fresh.eval(r).unwrap().to_json().to_string_compact())
        .collect();
    std::thread::scope(|s| {
        for _ in 0..4 {
            let engine = &engine;
            let reqs = &reqs;
            let want = &want;
            s.spawn(move || {
                let got = engine.eval_batch(reqs, 4);
                for (g, w) in got.into_iter().zip(want) {
                    assert_eq!(&g.unwrap().to_json().to_string_compact(), w);
                }
            });
        }
    });
}

#[test]
fn telemetry_loses_no_increments_under_concurrent_clients() {
    use camuy::api::{MemoryRequest, StatsRequest};
    use camuy::config::EnergyWeights;
    use camuy::telemetry::ReqKind;

    camuy::telemetry::set_enabled(true);
    let engine = Engine::new();
    let threads = 8usize;
    let per_thread = 200u64;
    let before = engine.stats(&StatsRequest::default()).snapshot;
    std::thread::scope(|s| {
        for t in 0..threads {
            let engine = &engine;
            s.spawn(move || {
                for i in 0..per_thread {
                    let h = 8 + 8 * ((t + i as usize) % 4);
                    let req = MemoryRequest {
                        net: "alexnet".to_string(),
                        batch: None,
                        config: ArrayConfig::new(h, 16),
                        weights: EnergyWeights::paper(),
                        graph: false,
                    };
                    engine.memory(&req).expect("memory request");
                }
            });
        }
    });
    let after = engine.stats(&StatsRequest::default()).snapshot;

    // Striped counters must not drop increments under contention. Other
    // tests in this binary run concurrently against the same process-wide
    // registry, so the observed delta is a floor, never an exact count.
    let want = threads as u64 * per_thread;
    let delta = after.request(ReqKind::Memory).count - before.request(ReqKind::Memory).count;
    assert!(delta >= want, "lost increments: {delta} < {want}");
    let lat_before = before.request(ReqKind::Memory).latency.count;
    let lat_after = after.request(ReqKind::Memory).latency.count;
    assert!(lat_after >= lat_before + want);
    let stats_before = before.request(ReqKind::Stats).count;
    let stats_after = after.request(ReqKind::Stats).count;
    assert!(stats_after > stats_before, "stats requests count themselves");
}
