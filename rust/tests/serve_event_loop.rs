//! Connection-lifecycle coverage for the event-loop TCP front end
//! (DESIGN.md §16): the slowloris idle timeout, write-queue backpressure
//! shedding, graceful drain under many open connections, abort accounting
//! for vanished clients, and the byte-identity property against the
//! `--threaded` oracle.
//!
//! The drain flag, the faultpoint table and the telemetry registry are
//! process-global, so every test here serializes on [`HARNESS`].

#![cfg(target_os = "linux")]

use camuy::api::{Engine, ServeOptions};
use camuy::faultpoint::{self, Action};
use camuy::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

static HARNESS: Mutex<()> = Mutex::new(());

fn harness() -> std::sync::MutexGuard<'static, ()> {
    let guard = HARNESS.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::disarm_all();
    camuy::api::clear_drain();
    // Counters are gated on the registry being enabled; another test
    // binary cannot have disabled it (process-global), but a prior test
    // in this one could — pin it on.
    camuy::telemetry::set_enabled(true);
    guard
}

fn error_kind(resp: &Json) -> &str {
    resp.get("error").unwrap().get("kind").unwrap().as_str().unwrap()
}

fn is_ok(resp: &Json) -> bool {
    resp.get("ok").unwrap().as_bool() == Some(true)
}

const EVAL_LINE: &str =
    "{\"id\":1,\"type\":\"eval\",\"net\":\"alexnet\",\"config\":{\"height\":24,\"width\":16}}\n";

#[test]
fn slowloris_client_times_out_while_healthy_clients_keep_getting_answers() {
    let _g = harness();
    let tel = camuy::telemetry::global();
    let idle_before = tel.connections_idle_closed.get();

    let engine = Engine::new();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        threads: 2,
        batch_max: 8,
        max_connections: Some(9),
        max_concurrent: 16,
        idle_secs: 1,
        ..ServeOptions::default()
    };
    std::thread::scope(|s| {
        s.spawn(|| camuy::api::serve_tcp(&engine, listener, &opts).unwrap());

        // The slowloris client connects and then... nothing.
        let slow = TcpStream::connect(addr).unwrap();
        let mut slow_reader = BufReader::new(slow);

        // Eight healthy clients are answered while it sits there.
        for i in 0..8 {
            let mut c = TcpStream::connect(addr).unwrap();
            let mut r = BufReader::new(c.try_clone().unwrap());
            c.write_all(EVAL_LINE.as_bytes()).unwrap();
            c.shutdown(std::net::Shutdown::Write).unwrap();
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let resp = Json::parse(line.trim()).unwrap();
            assert!(is_ok(&resp), "healthy client {i}: {}", resp.to_string_compact());
        }

        // The idle budget fires: a structured `idle_timeout` line, then EOF
        // — not a silent close.
        let mut line = String::new();
        slow_reader.read_line(&mut line).unwrap();
        let notice = Json::parse(line.trim()).unwrap();
        assert!(!is_ok(&notice), "{}", notice.to_string_compact());
        assert_eq!(error_kind(&notice), "idle_timeout");
        let idle_ms = notice.get("error").unwrap().get("idle_ms").unwrap();
        assert!(idle_ms.as_usize().unwrap() >= 1000, "{}", notice.to_string_compact());
        line.clear();
        assert_eq!(slow_reader.read_line(&mut line).unwrap(), 0, "timeout must close");
    });
    assert!(tel.connections_idle_closed.get() > idle_before);
}

#[test]
fn stalled_reader_hits_the_write_cap_and_is_shed_with_a_structured_close() {
    let _g = harness();
    let tel = camuy::telemetry::global();
    let shed_before = tel.requests_shed.get();

    let engine = Engine::new();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        threads: 2,
        batch_max: 64,
        max_connections: Some(1),
        idle_secs: 0,
        write_cap_bytes: 64 * 1024,
        ..ServeOptions::default()
    };
    std::thread::scope(|s| {
        s.spawn(|| camuy::api::serve_tcp(&engine, listener, &opts).unwrap());

        // Pipeline far more response volume than the kernel's socket
        // buffers can hide — 2000 sweeps of a 16x16 grid, tens of MB of
        // responses against auto-tuned TCP buffers of a few MB end to
        // end — while reading nothing: the server's write queue must
        // blow the 64 KiB cap, not its heap.
        let mut c = TcpStream::connect(addr).unwrap();
        let mut request = Vec::new();
        for i in 0..2000 {
            request.extend_from_slice(
                format!(
                    "{{\"id\":{i},\"type\":\"sweep\",\"net\":\"alexnet\",\
                     \"grid\":{{\"lo\":8,\"hi\":128,\"step\":8}},\"threads\":1}}\n"
                )
                .as_bytes(),
            );
        }
        c.write_all(&request).unwrap();
        c.shutdown(std::net::Shutdown::Write).unwrap();

        // Now read what the server managed to deliver: zero or more intact
        // `ok` lines (whatever the kernel buffered before the cap fired),
        // then exactly one structured `overloaded` refusal, then EOF.
        let mut reader = BufReader::new(c);
        let mut lines = Vec::new();
        let mut line = String::new();
        while reader.read_line(&mut line).unwrap() > 0 {
            lines.push(line.trim().to_string());
            line.clear();
        }
        assert!(!lines.is_empty(), "shed must explain itself before closing");
        let last = Json::parse(lines.last().unwrap()).unwrap();
        assert!(!is_ok(&last), "{}", last.to_string_compact());
        assert_eq!(error_kind(&last), "overloaded");
        assert!(
            last.get("error").unwrap().get("retry_after_ms").is_some(),
            "{}",
            last.to_string_compact()
        );
        for l in &lines[..lines.len() - 1] {
            let resp = Json::parse(l).unwrap_or_else(|e| panic!("corrupt line {l:?}: {e}"));
            assert!(is_ok(&resp), "non-final line must be an intact answer: {l}");
        }
        assert!(
            lines.len() < 2000,
            "every response was delivered — the cap never fired"
        );
    });
    assert!(tel.requests_shed.get() > shed_before);
}

#[test]
fn drain_under_a_hundred_connections_answers_in_flight_and_snapshots() {
    let _g = harness();
    let tel = camuy::telemetry::global();
    let bytes_before = tel.serve_bytes_in.get();

    let dir = std::env::temp_dir();
    let path = dir.join(format!("camuy-eventloop-drain-{}.json", std::process::id()));
    std::fs::remove_file(&path).ok();

    let engine = Engine::new();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        threads: 4,
        batch_max: 16,
        max_concurrent: 128,
        idle_secs: 30,
        snapshot: Some(path.clone()),
        ..ServeOptions::default()
    };
    std::thread::scope(|s| {
        s.spawn(|| camuy::api::serve_tcp(&engine, listener, &opts).unwrap());

        // 100 open connections, one request each, none of them closed.
        let mut clients = Vec::new();
        let mut sent = 0u64;
        for i in 0..100 {
            let mut c = TcpStream::connect(addr).unwrap();
            let line = format!(
                "{{\"id\":{i},\"type\":\"eval\",\"net\":\"alexnet\",\
                 \"config\":{{\"height\":24,\"width\":16}}}}\n"
            );
            c.write_all(line.as_bytes()).unwrap();
            sent += line.len() as u64;
            clients.push(c);
        }
        // Wait until the server has framed every request (the bytes-in
        // counter is bumped per framed line), so the drain arrives with
        // all 100 requests genuinely in flight.
        let deadline = Instant::now() + Duration::from_secs(20);
        while tel.serve_bytes_in.get() < bytes_before + sent {
            assert!(Instant::now() < deadline, "server never framed the requests");
            std::thread::sleep(Duration::from_millis(5));
        }
        camuy::api::request_drain();

        // Every client still gets its answer, then a clean EOF: drain
        // finishes in-flight work instead of dropping it.
        for (i, c) in clients.into_iter().enumerate() {
            let mut r = BufReader::new(c);
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let resp = Json::parse(line.trim())
                .unwrap_or_else(|e| panic!("client {i}: bad response {line:?}: {e}"));
            assert!(is_ok(&resp), "client {i}: {}", resp.to_string_compact());
            line.clear();
            assert_eq!(r.read_line(&mut line).unwrap(), 0, "client {i}: drain must close");
        }
    });
    camuy::api::clear_drain();
    assert!(path.exists(), "drain must write the final snapshot");
    std::fs::remove_file(&path).ok();
}

#[test]
fn aborted_connection_is_counted_and_cancels_its_in_flight_compute() {
    let _g = harness();
    let tel = camuy::telemetry::global();
    let aborted_before = tel.connections_aborted.get();

    // Two stacked `conn.read` armings: the first (a zero-length delay)
    // is burned by the read that delivers the sweep request; the second
    // — `cancel`, the deterministic stand-in for a client that vanished
    // mid-conversation — fires on the next read event, while the sweep
    // is mid-flight, and aborts exactly this connection.
    faultpoint::arm("conn.read", Action::Delay(Duration::ZERO), 1);
    faultpoint::arm("conn.read", Action::Cancel, 1);
    // Each sweep unit sleeps, so an uncancelled sweep would hold the
    // server for ~13 s — the fast exit below proves the abort reached
    // the in-flight batch's checkpoints.
    faultpoint::arm("sweep.unit", Action::Delay(Duration::from_millis(50)), 1000);

    let engine = Engine::new();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        threads: 2,
        batch_max: 1, // eval and sweep land in separate batches
        max_connections: Some(1),
        idle_secs: 30,
        ..ServeOptions::default()
    };
    let started = Instant::now();
    std::thread::scope(|s| {
        s.spawn(|| camuy::api::serve_tcp(&engine, listener, &opts).unwrap());

        let mut c = TcpStream::connect(addr).unwrap();
        // The sweep is framed and dispatched on the first read event;
        // the second write, sent while it grinds, triggers the read that
        // carries the injected cancel. Aborting the connection must
        // cancel the in-flight sweep through its token, not let it run
        // to the end.
        let sweep = "{\"id\":2,\"type\":\"sweep\",\"net\":\"alexnet\",\
                     \"grid\":{\"lo\":8,\"hi\":128,\"step\":8},\"threads\":1}\n";
        c.write_all(sweep.as_bytes()).unwrap();
        std::thread::sleep(Duration::from_millis(300));
        c.write_all(EVAL_LINE.as_bytes()).unwrap();

        // The server aborts: no full answer arrives. With the eval line
        // still unread server-side the close can surface as a reset, so
        // a read error is as acceptable as a clean EOF.
        let mut rest = Vec::new();
        let _ = c.read_to_end(&mut rest);
    });
    let elapsed = started.elapsed();
    faultpoint::disarm_all();
    assert!(tel.connections_aborted.get() > aborted_before, "abort was not counted");
    assert!(
        elapsed < Duration::from_secs(5),
        "server took {elapsed:?}; the in-flight sweep was not cancelled"
    );
}

/// Replay one request stream through a front end, returning the raw
/// response bytes.
fn replay(threaded: bool, input: &[u8]) -> Vec<u8> {
    let engine = Engine::new();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = ServeOptions {
        threads: 2,
        batch_max: 8,
        max_connections: Some(1),
        threaded,
        idle_secs: 60,
        ..ServeOptions::default()
    };
    let mut out = Vec::new();
    std::thread::scope(|s| {
        s.spawn(|| camuy::api::serve_tcp(&engine, listener, &opts).unwrap());
        let mut c = TcpStream::connect(addr).unwrap();
        let mut r = c.try_clone().unwrap();
        let writer = s.spawn(move || {
            c.write_all(input).unwrap();
            c.shutdown(std::net::Shutdown::Write).unwrap();
        });
        r.read_to_end(&mut out).unwrap();
        writer.join().unwrap();
    });
    out
}

#[test]
fn event_loop_and_threaded_front_ends_are_byte_identical_on_replay() {
    let _g = harness();

    let spec = r#"{"name":"replaynet","layers":[
        {"op":"conv2d","name":"c1","input":{"h":16,"w":16},
         "c_in":3,"c_out":8,"kernel":3,"stride":1,"padding":1},
        {"op":"linear","name":"fc","in_features":2048,"out_features":10}]}"#
        .replace('\n', " ");

    // Every framing and dispatch shape at once: ok evals, decode errors,
    // unknown networks, a register barrier with a dependent eval, control
    // plane, blank lines, an oversized line mid-stream (resync required),
    // and a final request with no trailing newline (EOF framing).
    let mut input = Vec::new();
    input.extend_from_slice(EVAL_LINE.as_bytes());
    input.extend_from_slice(b"this is not json\n");
    input.extend_from_slice(
        b"{\"id\":2,\"type\":\"eval\",\"net\":\"nonexistent\",\
          \"config\":{\"height\":16,\"width\":16}}\n",
    );
    input.extend_from_slice(b"\n   \n");
    input.extend_from_slice(
        b"{\"id\":3,\"type\":\"eval\",\"net\":\"alexnet\",\
          \"config\":{\"height\":0,\"width\":16}}\n",
    );
    input.extend_from_slice(format!("{{\"id\":4,\"type\":\"register\",\"network\":{spec}}}\n").as_bytes());
    input.extend_from_slice(
        b"{\"id\":5,\"type\":\"eval\",\"net\":\"replaynet\",\
          \"config\":{\"height\":16,\"width\":16}}\n",
    );
    input.extend_from_slice(b"{\"id\":6,\"type\":\"zoo\"}\n");
    let mut oversized = vec![b'x'; 5 << 20];
    oversized.push(b'\n');
    input.extend_from_slice(&oversized);
    input.extend_from_slice(
        b"{\"id\":7,\"type\":\"memory\",\"net\":\"alexnet\",\
          \"config\":{\"height\":16,\"width\":16}}\n",
    );
    // Unterminated final line: still a request.
    input.extend_from_slice(
        b"{\"id\":8,\"type\":\"eval\",\"net\":\"alexnet\",\
          \"config\":{\"height\":24,\"width\":16}}",
    );

    let eventloop = replay(false, &input);
    let threaded = replay(true, &input);
    assert!(!eventloop.is_empty());
    assert_eq!(
        eventloop.len(),
        threaded.len(),
        "front ends produced different byte counts:\n  event loop: {}\n  threaded:   {}",
        String::from_utf8_lossy(&eventloop),
        String::from_utf8_lossy(&threaded),
    );
    assert_eq!(eventloop, threaded, "front ends diverged");

    // And the stream answers every request, in order, exactly once.
    let ids: Vec<Option<usize>> = String::from_utf8(eventloop)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).unwrap().get("id").and_then(Json::as_usize))
        .collect();
    assert_eq!(
        ids,
        vec![
            Some(1),
            None, // bad json carries no id
            Some(2),
            Some(3),
            Some(4),
            Some(5),
            Some(6),
            None, // the oversized line's structured error
            Some(7),
            Some(8),
        ]
    );
}

#[test]
fn stats_surface_exposes_the_connection_lifecycle_counters() {
    let _g = harness();
    let engine = Engine::new();
    let mut out: Vec<u8> = Vec::new();
    camuy::api::serve(
        &engine,
        "{\"id\":1,\"type\":\"stats\"}\n".as_bytes(),
        &mut out,
        &ServeOptions::default(),
    )
    .unwrap();
    let resp = Json::parse(String::from_utf8(out).unwrap().trim()).unwrap();
    assert!(is_ok(&resp));
    let serve = resp.get("result").unwrap().get("serve").unwrap();
    for key in [
        "connections_active",
        "connections_idle_closed",
        "connections_aborted",
        "write_queue_bytes",
    ] {
        assert!(serve.get(key).is_some(), "missing serve.{key}");
    }
    let errors = serve.get("errors").unwrap();
    assert!(errors.get("idle_timeout").is_some(), "missing idle_timeout error kind");
}
