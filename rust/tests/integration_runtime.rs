//! Integration: the PJRT runtime loads every AOT artifact, executes it,
//! and the three-way verification (reference ⇔ emulator ⇔ artifact)
//! passes. Requires `make artifacts` to have run; tests announce and skip
//! (rather than fail) when the artifact directory is absent so `cargo
//! test` stays meaningful in a fresh checkout.

use camuy::config::ArrayConfig;
use camuy::coordinator::verify::{verify_gemm_artifact, PJRT_TOL};
use camuy::runtime::{default_artifact_dir, Manifest, PjrtRuntime};
use camuy::tensor::Matrix;
use camuy::util::prng::Rng;

fn manifest_or_skip() -> Option<Manifest> {
    let dir = default_artifact_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(m) = manifest_or_skip() else { return };
    for name in [
        "gemm_quickstart",
        "resnet152_s4_reduce",
        "mobilenet_pw",
        "conv3x3_56_64",
        "bottleneck_56_256",
        "fc_head",
    ] {
        let a = m.find(name).unwrap_or_else(|| panic!("{name} missing"));
        assert!(a.file.exists(), "{} missing on disk", a.file.display());
    }
}

#[test]
fn every_artifact_compiles_on_pjrt() {
    let Some(m) = manifest_or_skip() else { return };
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    for a in &m.artifacts {
        rt.load(&a.name, &a.file)
            .unwrap_or_else(|e| panic!("compiling {}: {e:#}", a.name));
    }
}

#[test]
fn quickstart_gemm_executes_with_correct_numerics() {
    let Some(m) = manifest_or_skip() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let entry = m.find("gemm_quickstart").unwrap();
    let exe = rt.load(&entry.name, &entry.file).unwrap();

    let mut rng = Rng::new(7);
    let a = Matrix::random_small_int(128, 128, &mut rng);
    let w = Matrix::random_small_int(128, 128, &mut rng);
    let got = exe.run_gemm(&a, &w).unwrap();
    let want = a.matmul(&w);
    let d = got.max_abs_diff(&want);
    assert!(d <= PJRT_TOL, "pjrt diff {d}");
}

#[test]
fn three_way_verification_passes_for_all_gemm_artifacts() {
    let Some(m) = manifest_or_skip() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let cfg = ArrayConfig::new(32, 32);
    let mut checked = 0;
    for entry in m.artifacts.iter().filter(|a| a.kind == "gemm") {
        let report = verify_gemm_artifact(&rt, entry, &cfg, 42).unwrap();
        println!("{report}");
        assert!(report.pass, "verification failed: {report}");
        // Integral fixtures: the emulator must be bit-exact.
        assert_eq!(report.emulator_vs_reference, 0.0);
        checked += 1;
    }
    assert!(checked >= 3, "expected >=3 gemm artifacts, got {checked}");
}

#[test]
fn conv_artifact_matches_emulated_im2col_gemm() {
    // The conv artifact computes conv(x, w); the emulator computes the
    // equivalent im2col GEMM. Both must agree with each other.
    let Some(m) = manifest_or_skip() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let entry = m.find("conv3x3_56_64").unwrap();
    let exe = rt.load(&entry.name, &entry.file).unwrap();

    let (h, c_in, c_out, k, pad) = (56usize, 64usize, 64usize, 3usize, 1usize);
    let mut rng = Rng::new(9);
    // NHWC input and HWIO weights as flat buffers.
    let x: Vec<f32> = (0..h * h * c_in)
        .map(|_| (rng.range_usize(0, 8) as i32 - 4) as f32)
        .collect();
    let wts: Vec<f32> = (0..k * k * c_in * c_out)
        .map(|_| (rng.range_usize(0, 8) as i32 - 4) as f32)
        .collect();

    let out = exe
        .run_raw(&[
            (&[1, h as i64, h as i64, c_in as i64], &x),
            (&[k as i64, k as i64, c_in as i64, c_out as i64], &wts),
        ])
        .unwrap();
    assert_eq!(out.len(), h * h * c_out);

    // Emulator path: im2col in rust, then run the GEMM functionally.
    let im2col = |x: &[f32]| -> Matrix {
        let mut a = Matrix::zeros(h * h, k * k * c_in);
        for oy in 0..h {
            for ox in 0..h {
                let row = oy * h + ox;
                let mut col = 0;
                for ky in 0..k {
                    for kx in 0..k {
                        for c in 0..c_in {
                            let iy = oy as i64 + ky as i64 - pad as i64;
                            let ix = ox as i64 + kx as i64 - pad as i64;
                            let v = if iy < 0 || ix < 0 || iy >= h as i64 || ix >= h as i64 {
                                0.0
                            } else {
                                x[(iy as usize * h + ix as usize) * c_in + c]
                            };
                            a[(row, col)] = v;
                            col += 1;
                        }
                    }
                }
            }
        }
        a
    };
    let a = im2col(&x);
    let wmat = Matrix::from_vec(k * k * c_in, c_out, wts.clone());
    let emu = camuy::arch::Emulator::new(ArrayConfig::new(64, 64)).unwrap();
    let res = emu.run_gemm(&a, &wmat, camuy::arch::EmulationMode::Wavefront);

    let mut max_d = 0f32;
    for (i, &v) in out.iter().enumerate() {
        let r = i / c_out;
        let c = i % c_out;
        max_d = max_d.max((v - res.output[(r, c)]).abs());
    }
    assert!(max_d <= PJRT_TOL, "conv vs emulated GEMM diff {max_d}");
}
