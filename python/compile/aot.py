"""AOT export: lower the Layer-2 computations to HLO *text* and write a
manifest the Rust runtime consumes.

HLO text — NOT `lowered.compile()` output or serialized HloModuleProto —
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entries():
    """The artifact catalogue.

    Each entry: (name, jitted fn, example args, input specs). Shapes are
    real layer operands from the zoo:
      * gemm_quickstart  — 128x128x128 (the quickstart example)
      * resnet152_s4_reduce — ResNet-152 stage-4 bottleneck 1x1 reduce
                              at 7x7: M=49, K=2048, N=512
      * mobilenet_pw     — MobileNetV3-L final pointwise: M=49, K=960, N=160
      * conv3x3_56_64    — a 3x3/s1/p1 conv on 56x56x64 (ResNet stage 1)
      * bottleneck_56_256 — full bottleneck block forward on 56x56x256
      * fc_head          — VGG-style 2-layer MLP head 512->128->10
    """
    e = []

    def add(name, fn, specs, kind, dims):
        e.append(
            {
                "name": name,
                "fn": fn,
                "specs": specs,
                "kind": kind,
                "dims": dims,
            }
        )

    add(
        "gemm_quickstart",
        lambda a, w: (model.gemm(a, w),),
        [f32(128, 128), f32(128, 128)],
        "gemm",
        {"m": 128, "k": 128, "n": 128},
    )
    add(
        "resnet152_s4_reduce",
        lambda a, w: (model.gemm(a, w),),
        [f32(49, 2048), f32(2048, 512)],
        "gemm",
        {"m": 49, "k": 2048, "n": 512},
    )
    add(
        "mobilenet_pw",
        lambda a, w: (model.gemm(a, w),),
        [f32(49, 960), f32(960, 160)],
        "gemm",
        {"m": 49, "k": 960, "n": 160},
    )
    add(
        "conv3x3_56_64",
        lambda x, w: (model.conv2d(x, w, 1, 1),),
        [f32(1, 56, 56, 64), f32(3, 3, 64, 64)],
        "conv",
        {"n": 1, "h": 56, "w": 56, "c_in": 64, "c_out": 64, "kernel": 3, "stride": 1, "pad": 1},
    )
    add(
        "bottleneck_56_256",
        lambda x, wr, ws, we: (model.bottleneck_block(x, wr, ws, we),),
        [
            f32(1, 14, 14, 256),
            f32(1, 1, 256, 64),
            f32(3, 3, 64, 64),
            f32(1, 1, 64, 256),
        ],
        "bottleneck",
        {"n": 1, "h": 14, "w": 14, "c": 256, "c_mid": 64},
    )
    add(
        "attention_heads",
        # Per-head attention-style grouped GEMM (BERT-Base geometry,
        # 4 heads of the 12 to keep the artifact small): serialized groups
        # exactly like the emulator runs group convolutions.
        lambda a, w: (model.grouped_gemm(a, w, 4),),
        [f32(128, 4 * 64), f32(4, 64, 128)],
        "grouped-gemm",
        {"m": 128, "k_g": 64, "n_g": 128, "groups": 4},
    )
    add(
        "fc_head",
        lambda x, w1, w2: (model.mlp(x, w1, w2),),
        [f32(4, 512), f32(512, 128), f32(128, 10)],
        "mlp",
        {"batch": 4, "d_in": 512, "d_hidden": 128, "d_out": 10},
    )
    return e


def export_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": []}
    for entry in entries():
        lowered = jax.jit(entry["fn"]).lower(*entry["specs"])
        text = to_hlo_text(lowered)
        fname = f"{entry['name']}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": entry["name"],
                "file": fname,
                "kind": entry["kind"],
                "dims": entry["dims"],
                "inputs": [list(s.shape) for s in entry["specs"]],
                "hlo_bytes": len(text),
            }
        )
        print(f"wrote {path} ({len(text)} bytes)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", help="(compat) ignored single-file path", default=None)
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    export_all(out_dir)


if __name__ == "__main__":
    main()
