"""Pure-jnp oracles for the Pallas kernels and the conv lowering.

Everything here is deliberately the *obvious* implementation; pytest
asserts the kernels and the AOT-exported computations match these.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a, w):
    """Plain f32 matmul."""
    return jnp.matmul(a.astype(jnp.float32), w.astype(jnp.float32))


def grouped_matmul_ref(a, w, groups: int):
    """a: (M, G*Kg), w: (G, Kg, Ng) -> (M, G*Ng)."""
    m, k_total = a.shape
    g, kg, ng = w.shape
    assert g == groups and k_total == groups * kg
    outs = [
        matmul_ref(a[:, i * kg : (i + 1) * kg], w[i]) for i in range(groups)
    ]
    return jnp.concatenate(outs, axis=1)


def im2col(x, kh: int, kw: int, stride: int, pad: int):
    """NHWC input -> (N*OH*OW, KH*KW*C) patch matrix (the conv->GEMM
    lowering the emulator's layer model assumes)."""
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = xp[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            cols.append(patch.reshape(n * oh * ow, c))
    # Patch layout: kh*kw channel blocks, matching w.reshape(-1, c_out).
    return jnp.concatenate(cols, axis=1), (n, oh, ow)


def conv2d_ref(x, w, stride: int, pad: int):
    """Conv reference via im2col + plain matmul: x NHWC,
    w (KH, KW, C_in, C_out) -> NHWC."""
    cols, (n, oh, ow) = im2col(x, w.shape[0], w.shape[1], stride, pad)
    wmat = w.reshape(-1, w.shape[3])
    out = matmul_ref(cols, wmat)
    return out.reshape(n, oh, ow, w.shape[3])
