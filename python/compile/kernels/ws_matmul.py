"""Layer 1: the weight-stationary tiled matmul Pallas kernel.

This is the compute hot-spot of the stack, written to mirror the schedule
of the emulated systolic array (DESIGN.md §2 Hardware-Adaptation):

* the grid iterates (M-blocks, N-blocks, K-blocks) exactly like the
  emulator's (chunk, col-tile, row-tile) loops;
* the weight block's BlockSpec index map ignores the M axis — the tile is
  "stationary" in VMEM while activation blocks stream past it;
* the K grid axis accumulates partial sums into the output block, playing
  the role of the accumulator array.

Pallas runs under ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that both the
pytest oracle checks and the Rust runtime can compile (see
/opt/xla-example/README.md). Real-TPU performance is estimated analytically
in DESIGN.md §8 from the BlockSpec geometry.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, w_ref, o_ref):
    """One (bm x bk) x (bk x bn) MAC tile; accumulates over the K grid axis."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # The MXU-shaped inner product. preferred_element_type keeps the
    # accumulation in f32 even for narrow inputs (the accumulator-array
    # analogue of out_bits=32).
    o_ref[...] += jnp.dot(
        a_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _block(dim: int, requested: int) -> int:
    """Clamp a block size to the dimension (tiny operands in tests)."""
    return min(dim, requested)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def ws_matmul(a: jax.Array, w: jax.Array, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """``a @ w`` via the weight-stationary Pallas kernel.

    a: (M, K), w: (K, N) -> (M, N) in f32. Dimensions need not divide the
    block sizes; Pallas masks the ragged edges.
    """
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)

    # Pad ragged edges up to block multiples (zeros are MAC-neutral); the
    # result is sliced back. On a real TPU this is the usual tile-alignment
    # padding; under interpret=True it also avoids NaN-filled OOB blocks.
    mp, kp, np_ = -(-m // bm) * bm, -(-k // bk) * bk, -(-n // bn) * bn
    a_p = jnp.pad(a.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    w_p = jnp.pad(w.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            # Activations: new M-block per i, new K-block per kk; the N axis
            # is ignored (re-streamed per col-tile, like the emulator's UB
            # activation re-reads).
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            # Weights: *stationary* across the M axis — index map ignores i.
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, w_p)
    return out[:m, :n]


def ws_matmul_grouped(a: jax.Array, w: jax.Array, groups: int, **kw):
    """Grouped GEMM: a (M, G*Kg) x w (G, Kg, Ng) -> (M, G*Ng), serialized
    per group exactly like the emulator runs group convolutions."""
    m, k_total = a.shape
    g, kg, ng = w.shape
    assert g == groups and k_total == groups * kg
    outs = [
        ws_matmul(a[:, i * kg : (i + 1) * kg], w[i], **kw) for i in range(groups)
    ]
    return jnp.concatenate(outs, axis=1)
