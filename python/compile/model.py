"""Layer 2: the JAX compute graph — conv-as-GEMM forward passes built on
the Layer-1 Pallas kernel. These are the computations `aot.py` lowers to
HLO text for the Rust runtime; Python never runs at request time.

The paper integrates its emulator into TensorFlow via custom operators;
here the ML-framework compute path is JAX → XLA → PJRT, and the Rust
coordinator runs the *same* GEMMs both through these compiled artifacts
(numerics) and through the emulator (metrics), cross-checking the two.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.ref import im2col
from compile.kernels.ws_matmul import ws_matmul, ws_matmul_grouped


def gemm(a, w):
    """The plain GEMM entry point (Layer-1 kernel pass-through)."""
    return ws_matmul(a, w)


def grouped_gemm(a, w, groups: int):
    """Serialized grouped GEMM (group convolutions, attention heads)."""
    return ws_matmul_grouped(a, w, groups)


def conv2d(x, w, stride: int = 1, pad: int = 0):
    """Convolution lowered exactly like the emulator's layer model:
    im2col patches (M = N*OH*OW rows, K = KH*KW*C_in) through the
    weight-stationary matmul kernel. x: NHWC, w: (KH, KW, C_in, C_out).
    """
    cols, (n, oh, ow) = im2col(x, w.shape[0], w.shape[1], stride, pad)
    wmat = w.reshape(-1, w.shape[3])
    out = ws_matmul(cols, wmat)
    return out.reshape(n, oh, ow, w.shape[3])


def bottleneck_block(x, w_reduce, w_spatial, w_expand):
    """A ResNet bottleneck forward (1x1 reduce -> 3x3 -> 1x1 expand, ReLU
    between, residual add): the end-to-end workload of the verify example.
    x: NHWC; w_reduce: (1,1,C,Cr); w_spatial: (3,3,Cr,Cr);
    w_expand: (1,1,Cr,C).
    """
    y = jax.nn.relu(conv2d(x, w_reduce, 1, 0))
    y = jax.nn.relu(conv2d(y, w_spatial, 1, 1))
    y = conv2d(y, w_expand, 1, 0)
    return jax.nn.relu(y + x)


def mlp(x, w1, w2):
    """A 2-layer MLP head (the FC tail of the classic CNNs)."""
    return ws_matmul(jax.nn.relu(ws_matmul(x, w1)), w2)
