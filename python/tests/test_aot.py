"""AOT export pipeline: HLO text generation, manifest integrity, and a
round-trip execution of the exported computation via jax itself (the Rust
runtime does the same through PJRT; its integration test lives in
rust/tests/).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.ref import matmul_ref


def test_hlo_text_is_parseable_hlo(tmp_path):
    lowered = jax.jit(lambda a, w: (model.gemm(a, w),)).lower(
        aot.f32(8, 8), aot.f32(8, 8)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[8,8]" in text
    # Tuple return for the rust-side to_tuple1 unwrap.
    assert "(f32[8,8]" in text


def test_export_all_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.export_all(out)
    names = {a["name"] for a in manifest["artifacts"]}
    assert {
        "gemm_quickstart",
        "resnet152_s4_reduce",
        "mobilenet_pw",
        "conv3x3_56_64",
        "bottleneck_56_256",
        "fc_head",
    } <= names
    # Files exist and the manifest round-trips.
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded["format"] == "hlo-text"
    for a in loaded["artifacts"]:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path), path
        assert os.path.getsize(path) == a["hlo_bytes"]


def test_entry_specs_match_fn_arity():
    for e in aot.entries():
        lowered = jax.jit(e["fn"]).lower(*e["specs"])
        assert lowered is not None


def test_exported_gemm_numerics_roundtrip():
    # The jitted export function computes the same numbers the oracle does
    # (the rust PJRT test repeats this through the compiled artifact).
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((128, 128)), dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 128)), dtype=jnp.float32)
    entry = next(e for e in aot.entries() if e["name"] == "gemm_quickstart")
    (got,) = jax.jit(entry["fn"])(a, w)
    np.testing.assert_allclose(got, matmul_ref(a, w), rtol=1e-4, atol=1e-4)
