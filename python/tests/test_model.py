"""Layer-2 correctness: conv-as-GEMM forward passes against jax.lax
convolutions, and the composite blocks against their obvious references.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import conv2d_ref, matmul_ref


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


def lax_conv(x, w, stride, pad):
    """Ground truth via XLA's native convolution (NHWC / HWIO)."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


@pytest.mark.parametrize(
    "h,c_in,c_out,k,stride,pad",
    [
        (8, 4, 8, 3, 1, 1),
        (8, 4, 8, 3, 2, 1),
        (9, 3, 5, 3, 2, 0),
        (7, 8, 8, 1, 1, 0),
        (12, 2, 4, 5, 1, 2),
    ],
)
def test_conv2d_matches_lax(h, c_in, c_out, k, stride, pad):
    rng = np.random.default_rng(0)
    x = rand(rng, 1, h, h, c_in)
    w = rand(rng, k, k, c_in, c_out)
    got = model.conv2d(x, w, stride, pad)
    want = lax_conv(x, w, stride, pad)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_matches_im2col_ref():
    rng = np.random.default_rng(1)
    x = rand(rng, 2, 10, 10, 3)
    w = rand(rng, 3, 3, 3, 6)
    np.testing.assert_allclose(
        model.conv2d(x, w, 1, 1), conv2d_ref(x, w, 1, 1), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(4, 14),
    c_in=st.integers(1, 8),
    c_out=st.integers(1, 8),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_hypothesis(h, c_in, c_out, stride, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, 1, h, h, c_in)
    w = rand(rng, 3, 3, c_in, c_out)
    got = model.conv2d(x, w, stride, 1)
    want = lax_conv(x, w, stride, 1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_bottleneck_block_shape_and_residual():
    rng = np.random.default_rng(2)
    c, cm = 16, 4
    x = rand(rng, 1, 7, 7, c)
    wr = rand(rng, 1, 1, c, cm)
    ws = rand(rng, 3, 3, cm, cm)
    we = rand(rng, 1, 1, cm, c)
    y = model.bottleneck_block(x, wr, ws, we)
    assert y.shape == x.shape
    # Zero weights -> pure residual passthrough (ReLU(x + 0) with x>=0).
    z = model.bottleneck_block(
        jnp.abs(x), jnp.zeros_like(wr), jnp.zeros_like(ws), jnp.zeros_like(we)
    )
    np.testing.assert_allclose(z, jnp.abs(x), rtol=1e-6, atol=1e-6)


def test_mlp_matches_reference():
    rng = np.random.default_rng(3)
    x, w1, w2 = rand(rng, 4, 32), rand(rng, 32, 16), rand(rng, 16, 10)
    got = model.mlp(x, w1, w2)
    want = matmul_ref(jax.nn.relu(matmul_ref(x, w1)), w2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
