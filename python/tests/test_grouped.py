"""Grouped-GEMM path: the serialized per-group kernel against the oracle
and against a block-diagonal dense matmul (the mathematical definition)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import grouped_matmul_ref, matmul_ref
from compile.kernels.ws_matmul import ws_matmul_grouped


def block_diag_reference(a, w, groups):
    """Dense equivalent: block-diagonal weight matrix."""
    g, kg, ng = w.shape
    dense = jnp.zeros((groups * kg, groups * ng), dtype=jnp.float32)
    for i in range(groups):
        dense = dense.at[i * kg : (i + 1) * kg, i * ng : (i + 1) * ng].set(w[i])
    return matmul_ref(a, dense)


@settings(max_examples=20, deadline=None)
@given(
    groups=st.integers(1, 6),
    m=st.integers(1, 24),
    kg=st.integers(1, 16),
    ng=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_grouped_equals_block_diagonal(groups, m, kg, ng, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, groups * kg)), dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((groups, kg, ng)), dtype=jnp.float32)
    got = ws_matmul_grouped(a, w, groups)
    np.testing.assert_allclose(
        got, block_diag_reference(a, w, groups), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        got, grouped_matmul_ref(a, w, groups), rtol=1e-5, atol=1e-5
    )


def test_attention_head_geometry():
    # The exported artifact's exact geometry.
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((128, 4 * 64)), dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 64, 128)), dtype=jnp.float32)
    got = ws_matmul_grouped(a, w, 4)
    assert got.shape == (128, 4 * 128)
    np.testing.assert_allclose(
        got, grouped_matmul_ref(a, w, 4), rtol=1e-4, atol=1e-4
    )
