"""Layer-1 correctness: the Pallas weight-stationary matmul against the
pure-jnp oracle, swept over shapes (hypothesis) and block configurations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import grouped_matmul_ref, matmul_ref
from compile.kernels.ws_matmul import ws_matmul, ws_matmul_grouped


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (8, 8, 8),
        (128, 128, 128),
        (5, 7, 3),       # nothing divides anything
        (1, 2048, 512),  # FC-like
        (200, 27, 64),   # conv-stem-like
    ],
)
def test_matches_reference_shapes(m, k, n):
    rng = np.random.default_rng(0)
    a, w = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(ws_matmul(a, w), matmul_ref(a, w), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (128, 128, 128), (7, 5, 3)])
def test_block_shapes_do_not_change_results(bm, bn, bk):
    rng = np.random.default_rng(1)
    a, w = rand(rng, 33, 29, ), rand(rng, 29, 17)
    got = ws_matmul(a, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, matmul_ref(a, w), rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    bm=st.sampled_from([4, 8, 16, 64]),
    bn=st.sampled_from([4, 8, 16, 64]),
    bk=st.sampled_from([4, 8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(m, k, n, bm, bn, bk, seed):
    rng = np.random.default_rng(seed)
    a, w = rand(rng, m, k), rand(rng, k, n)
    got = ws_matmul(a, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, matmul_ref(a, w), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_input_dtypes_accumulate_in_f32(dtype):
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(-4, 5, (16, 24)), dtype=dtype)
    w = jnp.asarray(rng.integers(-4, 5, (24, 8)), dtype=dtype)
    got = ws_matmul(a, w)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, matmul_ref(a, w), rtol=1e-5, atol=1e-5)


def test_grouped_matches_reference():
    rng = np.random.default_rng(3)
    groups, m, kg, ng = 4, 10, 6, 5
    a = rand(rng, m, groups * kg)
    w = rand(rng, groups, kg, ng)
    got = ws_matmul_grouped(a, w, groups)
    np.testing.assert_allclose(
        got, grouped_matmul_ref(a, w, groups), rtol=1e-5, atol=1e-5
    )


def test_jit_cache_reuse():
    # Same static blocks -> one compilation; just a smoke check it runs
    # under jit twice without retracing errors.
    rng = np.random.default_rng(4)
    a, w = rand(rng, 32, 32), rand(rng, 32, 32)
    first = ws_matmul(a, w)
    second = ws_matmul(a * 2, w)
    np.testing.assert_allclose(second, 2 * first, rtol=1e-5, atol=1e-6)


def test_weight_stationarity_of_blockspec():
    # The weight BlockSpec must ignore the M grid axis: growing M must not
    # change which weight block any (j, kk) iteration reads. We verify
    # behaviourally: results for a tall A equal row-blocks computed
    # independently.
    rng = np.random.default_rng(5)
    a, w = rand(rng, 64, 16), rand(rng, 16, 12)
    whole = ws_matmul(a, w, bm=16, bn=8, bk=8)
    parts = jnp.concatenate(
        [ws_matmul(a[i : i + 16], w, bm=16, bn=8, bk=8) for i in range(0, 64, 16)]
    )
    np.testing.assert_allclose(whole, parts, rtol=1e-6, atol=1e-6)
